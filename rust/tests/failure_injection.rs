//! Failure-injection and edge-case tests: the pipeline and solvers must fail
//! loudly (or degrade gracefully) on bad inputs rather than hang, panic, or
//! return silently-wrong data.

#![allow(clippy::field_reassign_with_default)]
use skr::coordinator::{Pipeline, PipelineConfig};
use skr::la::Csr;
use skr::pde::FamilyKind;
use skr::precond::{Identity, PrecondKind};
use skr::solver::{gcrodr, gmres, Engine, Recycler, SolverConfig, StopReason};
use skr::util::prng::Rng;

// ---------------------------------------------------------------------------
// Solver edge cases.
// ---------------------------------------------------------------------------

#[test]
fn singular_matrix_does_not_hang() {
    // Rank-deficient A with b outside the range: the solver must stop at
    // max_iters (or breakdown), never loop forever, and must not report
    // convergence.
    let n = 40;
    let mut trips = Vec::new();
    for i in 0..n - 1 {
        trips.push((i, i, 1.0));
    }
    // Last row entirely zero.
    let a = Csr::from_triplets(n, n, &trips);
    let mut b = vec![0.0; n];
    b[n - 1] = 1.0; // unreachable component
    let cfg = SolverConfig::default().with_tol(1e-12).with_max_iters(200);
    let mut x = vec![0.0; n];
    let s = gmres(&a, &b, &mut x, &Identity, &cfg);
    assert_ne!(s.stop, StopReason::Converged, "{s:?}");
    assert!(s.iters <= 210);
    let mut x2 = vec![0.0; n];
    let mut rec = Recycler::new();
    let s2 = gcrodr(&a, &b, &mut x2, &Identity, &cfg, &mut rec);
    assert_ne!(s2.stop, StopReason::Converged, "{s2:?}");
}

#[test]
fn consistent_singular_system_converges_to_a_solution() {
    // Rank-deficient but consistent: lucky breakdown should produce a valid
    // solution (b in range(A)).
    let n = 30;
    let mut trips = Vec::new();
    for i in 0..n - 1 {
        trips.push((i, i, 2.0));
    }
    let a = Csr::from_triplets(n, n, &trips);
    let mut xtrue = vec![1.0; n];
    xtrue[n - 1] = 0.0;
    let b = a.matvec(&xtrue);
    let cfg = SolverConfig::default().with_tol(1e-10).with_max_iters(500);
    let mut x = vec![0.0; n];
    let s = gmres(&a, &b, &mut x, &Identity, &cfg);
    assert!(s.rel_residual < 1e-9, "{s:?}");
}

#[test]
fn nonzero_initial_guess_is_honoured() {
    let mut rng = Rng::new(77);
    let n = 60;
    let mut trips = Vec::new();
    for i in 0..n {
        trips.push((i, i, 4.0));
        if i + 1 < n {
            trips.push((i, i + 1, -1.0));
            trips.push((i + 1, i, -1.0));
        }
    }
    let a = Csr::from_triplets(n, n, &trips);
    let xtrue = rng.normals(n);
    let b = a.matvec(&xtrue);
    // Start exactly at the solution: zero iterations.
    let mut x = xtrue.clone();
    let s = gmres(&a, &b, &mut x, &Identity, &SolverConfig::default());
    assert_eq!(s.iters, 0);
    assert!(s.converged());
    let mut x2 = xtrue.clone();
    let mut rec = Recycler::new();
    let s2 = gcrodr(&a, &b, &mut x2, &Identity, &SolverConfig::default(), &mut rec);
    assert_eq!(s2.iters, 0);
    assert!(s2.converged());
}

#[test]
fn tiny_systems_work() {
    // n = 1 and n = 2 exercise every degenerate bound in the Arnoldi loop.
    for n in [1usize, 2, 3] {
        let trips: Vec<(usize, usize, f64)> = (0..n).map(|i| (i, i, (i + 2) as f64)).collect();
        let a = Csr::from_triplets(n, n, &trips);
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let s = gmres(&a, &b, &mut x, &Identity, &SolverConfig::default().with_tol(1e-12));
        assert!(s.converged(), "n={n} {s:?}");
        let mut x2 = vec![0.0; n];
        let mut rec = Recycler::new();
        let s2 = gcrodr(&a, &b, &mut x2, &Identity, &SolverConfig::default().with_tol(1e-12), &mut rec);
        assert!(s2.converged(), "n={n} {s2:?}");
        for i in 0..n {
            assert!((x[i] - 1.0 / (i + 2) as f64).abs() < 1e-10);
            assert!((x2[i] - 1.0 / (i + 2) as f64).abs() < 1e-10);
        }
    }
}

#[test]
fn m_smaller_than_k_is_clamped_not_panicking() {
    let mut rng = Rng::new(5);
    let n = 50;
    let mut trips = Vec::new();
    for i in 0..n {
        trips.push((i, i, 3.0 + rng.normal().abs()));
    }
    let a = Csr::from_triplets(n, n, &trips);
    let b = rng.normals(n);
    // Pathological configs: k ≥ m, m tiny.
    for (m, k) in [(2usize, 10usize), (3, 3), (2, 1)] {
        let cfg = SolverConfig::default().with_tol(1e-8).with_m(m).with_k(k);
        let mut x = vec![0.0; n];
        let mut rec = Recycler::new();
        let s = gcrodr(&a, &b, &mut x, &Identity, &cfg, &mut rec);
        assert!(s.converged(), "m={m} k={k}: {s:?}");
    }
}

// ---------------------------------------------------------------------------
// Pipeline failure injection.
// ---------------------------------------------------------------------------

fn base_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.family = FamilyKind::Darcy;
    cfg.unknowns = 64;
    cfg.count = 6;
    cfg.engine = Engine::SkrRecycle;
    cfg.precond = PrecondKind::Jacobi;
    cfg.solver.tol = 1e-8;
    cfg.threads = 2;
    cfg.seed = 1;
    cfg
}

#[test]
fn unwritable_output_directory_is_an_error_not_a_panic() {
    let mut cfg = base_cfg();
    // A path under a *file* cannot be created.
    let blocker = std::env::temp_dir().join("skr_blocker_file");
    std::fs::write(&blocker, b"x").unwrap();
    cfg.out_dir = Some(blocker.join("sub"));
    let r = Pipeline::new(cfg).run();
    assert!(r.is_err(), "expected error for unwritable out_dir");
    let _ = std::fs::remove_file(&blocker);
}

#[test]
fn zero_count_pipeline_is_a_clean_noop() {
    let mut cfg = base_cfg();
    cfg.count = 0;
    let r = Pipeline::new(cfg).run().unwrap();
    assert_eq!(r.metrics.systems, 0);
    assert!(r.per_system.is_empty());
    assert!(r.order.is_empty());
}

#[test]
fn more_threads_than_systems_is_fine() {
    let mut cfg = base_cfg();
    cfg.count = 3;
    cfg.threads = 16;
    let r = Pipeline::new(cfg).run().unwrap();
    assert_eq!(r.metrics.systems, 3);
}

#[test]
fn queue_depth_one_still_completes() {
    // Tightest possible backpressure: every solve blocks on the writer.
    let dir = std::env::temp_dir().join("skr_q1");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = base_cfg();
    cfg.queue_depth = 1;
    cfg.out_dir = Some(dir.clone());
    let r = Pipeline::new(cfg).run().unwrap();
    assert_eq!(r.metrics.systems, 6);
    assert_eq!(r.dataset.unwrap().count, 6);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn max_iter_hits_are_counted() {
    let mut cfg = base_cfg();
    cfg.unknowns = 400;
    cfg.count = 2;
    cfg.engine = Engine::Gmres;
    cfg.precond = PrecondKind::None;
    cfg.solver.tol = 1e-14;
    cfg.solver.max_iters = 15; // guaranteed to be insufficient
    let r = Pipeline::new(cfg).run().unwrap();
    assert_eq!(r.metrics.max_iter_hits, 2, "{:?}", r.metrics);
}

#[test]
fn solver_tolerance_is_respected_by_dataset() {
    // Solutions exported by the pipeline must actually satisfy ‖b−Ax‖/‖b‖ ≤
    // tol·1.5 when re-checked against freshly regenerated systems.
    use skr::pde::generate;
    let dir = std::env::temp_dir().join("skr_tolcheck");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = base_cfg();
    cfg.solver.tol = 1e-9;
    cfg.out_dir = Some(dir.clone());
    let seed = cfg.seed;
    let unknowns = cfg.unknowns;
    let count = cfg.count;
    Pipeline::new(cfg).run().unwrap();
    let (_, sols, _) = skr::coordinator::dataset::load(&dir).unwrap();
    let fam = FamilyKind::Darcy.build(unknowns);
    let systems = generate(fam.as_ref(), count, seed).unwrap();
    for (i, sys) in systems.iter().enumerate() {
        let n = sys.b.len();
        let x = &sols.data[i * n..(i + 1) * n];
        let ax = sys.a.matvec(x);
        let rnorm: f64 = sys.b.iter().zip(&ax).map(|(b, a)| (b - a) * (b - a)).sum::<f64>().sqrt();
        let bnorm: f64 = sys.b.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(rnorm / bnorm < 1.5e-9, "system {i}: rel {}", rnorm / bnorm);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
