//! End-to-end tests for the `skr serve` subsystem: a real daemon on an
//! ephemeral port, driven through the HTTP/JSON API exactly as the CLI
//! clients and curl would drive it.

use skr::coordinator::{Pipeline, PipelineConfig};
use skr::service::http::request;
use skr::service::journal::Journal;
use skr::service::{serve, JobSpec, ServeConfig};
use skr::util::json::Json;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

fn unique_dir(tag: &str) -> PathBuf {
    static COUNTER: AtomicUsize = AtomicUsize::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("skr_svc_{tag}_{}_{n}", std::process::id()))
}

/// A daemon on an ephemeral port, shut down (gracefully) on drop via
/// `POST /shutdown`.
struct TestServer {
    addr: String,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
    state_dir: PathBuf,
    /// Remove `state_dir` on drop; tests that inspect the journal after
    /// shutdown turn this off and clean up themselves.
    cleanup_state: bool,
}

impl TestServer {
    fn start(workers: usize, queue_capacity: usize, state_dir: PathBuf) -> TestServer {
        // Reserve an ephemeral port, free it, and hand it to the daemon.
        // (Tiny race window, but unique-per-process and fine for tests.)
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let cfg = ServeConfig {
            bind: addr.clone(),
            workers,
            queue_capacity,
            state_dir: state_dir.clone(),
        };
        let handle = std::thread::spawn(move || serve(&cfg));
        let server = TestServer { addr, handle: Some(handle), state_dir, cleanup_state: true };
        server.wait_healthy();
        server
    }

    fn wait_healthy(&self) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while Instant::now() < deadline {
            if let Ok((200, _)) = request(&self.addr, "GET", "/healthz", None) {
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        panic!("daemon on {} never became healthy", self.addr);
    }

    fn submit(&self, spec: &JobSpec) -> (u16, Json) {
        let (status, body) =
            request(&self.addr, "POST", "/jobs", Some(&spec.to_json().dump())).unwrap();
        (status, Json::parse(&body).unwrap())
    }

    fn job(&self, id: u64) -> Json {
        let (status, body) =
            request(&self.addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        assert_eq!(status, 200, "GET /jobs/{id}: {body}");
        Json::parse(&body).unwrap()
    }

    fn wait_terminal(&self, id: u64, timeout: Duration) -> String {
        let deadline = Instant::now() + timeout;
        loop {
            let j = self.job(id);
            let state = j.get("state").and_then(|s| s.as_str()).unwrap().to_string();
            if ["done", "failed", "cancelled"].contains(&state.as_str()) {
                return state;
            }
            assert!(Instant::now() < deadline, "job {id} stuck in {state}");
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    fn shutdown(mut self) {
        self.drain_and_join();
    }

    fn drain_and_join(&mut self) {
        if let Some(handle) = self.handle.take() {
            let _ = request(&self.addr, "POST", "/shutdown", None);
            let result = handle.join();
            // Asserting while a test is already unwinding would double-panic.
            if !std::thread::panicking() {
                result.unwrap().unwrap();
            }
        }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.drain_and_join();
        if self.cleanup_state {
            let _ = std::fs::remove_dir_all(&self.state_dir);
        }
    }
}

fn small_spec(seed: u64, out: Option<&std::path::Path>) -> JobSpec {
    JobSpec {
        family: "darcy".into(),
        unknowns: 100,
        count: 8,
        engine: "skr".into(),
        precond: "jacobi".into(),
        sort: "greedy".into(),
        threads: 2,
        seed,
        out: out.map(|p| p.display().to_string()),
        ..JobSpec::default()
    }
}

#[test]
fn concurrent_jobs_match_direct_generate_byte_for_byte() {
    let state = unique_dir("e2e_state");
    let server = TestServer::start(2, 16, state);

    // Submit N jobs with distinct seeds through the API.
    let seeds = [3u64, 11, 29];
    let mut ids = Vec::new();
    let mut dirs = Vec::new();
    for &seed in &seeds {
        let dir = unique_dir(&format!("e2e_out_{seed}"));
        let (status, resp) = server.submit(&small_spec(seed, Some(&dir)));
        assert_eq!(status, 202, "{resp:?}");
        ids.push(resp.get("id").and_then(|v| v.as_usize()).unwrap() as u64);
        dirs.push(dir);
    }
    for &id in &ids {
        assert_eq!(server.wait_terminal(id, Duration::from_secs(120)), "done");
    }

    // /metrics aggregates all completed jobs' RunMetrics.
    let (status, metrics) = request(&server.addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(metrics.contains("skr_service_jobs_done_total 3"), "{metrics}");
    assert!(
        metrics.contains(&format!("skr_systems_total {}", seeds.len() * 8)),
        "{metrics}"
    );
    assert!(metrics.contains("skr_solve_iters_bucket"), "{metrics}");

    server.shutdown();

    // Each API-produced dataset is byte-identical to a direct Pipeline run
    // (i.e. what `skr generate` does) with the same spec.
    for (&seed, dir) in seeds.iter().zip(&dirs) {
        let reference = unique_dir(&format!("e2e_ref_{seed}"));
        let mut cfg = small_spec(seed, Some(&reference)).to_config().unwrap();
        cfg.out_dir = Some(reference.clone());
        Pipeline::new(cfg).run().unwrap();
        for file in ["inputs.npy", "solutions.npy"] {
            let got = std::fs::read(dir.join(file)).unwrap();
            let want = std::fs::read(reference.join(file)).unwrap();
            assert_eq!(got, want, "{file} differs for seed {seed}");
        }
        let _ = std::fs::remove_dir_all(dir);
        let _ = std::fs::remove_dir_all(&reference);
    }
}

#[test]
fn cancel_in_flight_stops_promptly_and_leaves_no_dataset() {
    let state = unique_dir("cancel_state");
    let server = TestServer::start(1, 8, state);

    // A job big enough to still be running when the cancel lands.
    let out = unique_dir("cancel_out");
    let spec = JobSpec {
        unknowns: 900,
        count: 400,
        tol: 1e-10,
        ..small_spec(5, Some(&out))
    };
    let (status, resp) = server.submit(&spec);
    assert_eq!(status, 202);
    let id = resp.get("id").and_then(|v| v.as_usize()).unwrap() as u64;

    // Wait until it is actually running and has made some progress.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let j = server.job(id);
        let running = j.get("state").and_then(|s| s.as_str()) == Some("running");
        let done =
            j.get("progress").and_then(|p| p.get("done")).and_then(|v| v.as_usize()).unwrap_or(0);
        if running && done > 0 {
            break;
        }
        assert!(Instant::now() < deadline, "job never started: {j:?}");
        std::thread::sleep(Duration::from_millis(10));
    }

    let (status, body) = request(&server.addr, "DELETE", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 202, "{body}");
    assert_eq!(server.wait_terminal(id, Duration::from_secs(30)), "cancelled");
    // Progress stopped well short of the full job.
    let j = server.job(id);
    let done =
        j.get("progress").and_then(|p| p.get("done")).and_then(|v| v.as_usize()).unwrap();
    assert!(done < 400, "cancel did not interrupt: {done}/400 systems ran");
    // No partial dataset directory (atomic finalize never ran).
    assert!(!out.exists(), "cancelled job left {}", out.display());

    server.shutdown();
}

#[test]
fn full_queue_answers_429_without_dropping_accepted_work() {
    let state = unique_dir("full_state");
    // One worker, capacity 2: first job occupies the worker, two fill the
    // backlog, the fourth must bounce.
    let server = TestServer::start(1, 2, state);

    let blocker = JobSpec { unknowns: 900, count: 200, tol: 1e-10, ..small_spec(1, None) };
    let (status, resp) = server.submit(&blocker);
    assert_eq!(status, 202);
    let blocker_id = resp.get("id").and_then(|v| v.as_usize()).unwrap() as u64;
    // Wait for the worker to pick it up so it no longer occupies backlog.
    let deadline = Instant::now() + Duration::from_secs(60);
    while server.job(blocker_id).get("state").and_then(|s| s.as_str()) != Some("running") {
        assert!(Instant::now() < deadline);
        std::thread::sleep(Duration::from_millis(10));
    }

    let quick = |seed| JobSpec { count: 2, ..small_spec(seed, None) };
    let (s1, r1) = server.submit(&quick(2));
    let (s2, _r2) = server.submit(&quick(3));
    assert_eq!((s1, s2), (202, 202));
    let (s3, body) = request(
        &server.addr,
        "POST",
        "/jobs",
        Some(&quick(4).to_json().dump()),
    )
    .unwrap();
    assert_eq!(s3, 429, "{body}");

    // The accepted jobs are intact and eventually complete.
    let id1 = r1.get("id").and_then(|v| v.as_usize()).unwrap() as u64;
    let (_, cancel_body) =
        request(&server.addr, "DELETE", &format!("/jobs/{blocker_id}"), None).unwrap();
    assert!(cancel_body.contains("cancel"), "{cancel_body}");
    assert_eq!(server.wait_terminal(id1, Duration::from_secs(120)), "done");

    server.shutdown();
}

#[test]
fn journal_replay_requeues_unfinished_jobs() {
    let state = unique_dir("replay_state");
    std::fs::create_dir_all(&state).unwrap();
    let out = unique_dir("replay_out");

    // Simulate a daemon killed mid-job: journal says submitted+started with
    // no terminal record.
    {
        let journal = Journal::open(&state.join("journal.jsonl")).unwrap();
        let spec = small_spec(17, Some(&out));
        journal.submitted(1, &spec);
        journal.started(1);
        let done_spec = small_spec(99, None);
        journal.submitted(2, &done_spec);
        journal.started(2);
        journal.done(2);
    }

    // Restart: job 1 must be re-queued and run to completion; job 2 must not.
    let server = TestServer::start(1, 8, state.clone());
    assert_eq!(server.wait_terminal(1, Duration::from_secs(120)), "done");
    let (status, body) = request(&server.addr, "GET", "/jobs/2", None).unwrap();
    assert_eq!(status, 404, "terminal journaled job must not reappear: {body}");
    assert!(out.join("inputs.npy").exists());

    // A fresh submit gets an id above everything the journal ever saw.
    let (_, resp) = server.submit(&JobSpec { count: 1, ..small_spec(1, None) });
    assert!(resp.get("id").and_then(|v| v.as_usize()).unwrap() >= 3, "{resp:?}");

    server.shutdown();
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn graceful_shutdown_drains_queued_jobs() {
    let state = unique_dir("drain_state");
    let out = unique_dir("drain_out");
    let mut server = TestServer::start(1, 8, state.clone());
    server.cleanup_state = false; // the journal is inspected after shutdown
    let (status, resp) = server.submit(&small_spec(7, Some(&out)));
    assert_eq!(status, 202);
    let id = resp.get("id").and_then(|v| v.as_usize()).unwrap() as u64;

    // Shut down immediately: serve() must not return until the job finished.
    server.shutdown();

    let replay = Journal::replay(&state.join("journal.jsonl")).unwrap();
    assert!(replay.pending.is_empty(), "drain left unfinished journaled jobs");
    assert!(out.join("solutions.npy").exists(), "job {id} did not finish during drain");
    let _ = std::fs::remove_dir_all(&out);
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn api_rejects_malformed_and_unknown() {
    let state = unique_dir("badreq_state");
    let server = TestServer::start(1, 4, state);

    let (status, _) = request(&server.addr, "POST", "/jobs", Some("{not json")).unwrap();
    assert_eq!(status, 400);
    // The truncated-\u payload that used to panic the JSON parser.
    let (status, _) = request(&server.addr, "POST", "/jobs", Some("{\"family\":\"\\u12")).unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        request(&server.addr, "POST", "/jobs", Some(r#"{"family":"nope"}"#)).unwrap();
    assert_eq!(status, 400);
    let (status, _) = request(&server.addr, "GET", "/jobs/999", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = request(&server.addr, "GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, body) = request(&server.addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\":true"), "{body}");

    server.shutdown();
}
