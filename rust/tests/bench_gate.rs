//! Integration tests for the `skr bench` subsystem: counter determinism
//! across repeated runs (propcheck over random tiny workloads), baseline
//! round-trip through disk, and the regression gate — including the
//! degraded-solver scenario (recycling disabled must fail the gate).

use skr::bench::{check, run_engine, run_manifest, run_workload, Baseline, Manifest};
use skr::pde::FamilyKind;
use skr::solver::Engine;
use skr::util::propcheck::{check_msg, Config};

/// One small Darcy workload, fast enough to solve repeatedly in a test.
fn tiny_manifest() -> Manifest {
    let mut m = Manifest::quick();
    m.workloads.truncate(1);
    m.warmup = 0;
    m.runs = 2;
    let w = &mut m.workloads[0];
    assert_eq!(w.family, FamilyKind::Darcy);
    w.unknowns = 100;
    w.count = 6;
    m
}

fn unique_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("skr_bench_gate_{}_{tag}.json", std::process::id()))
}

#[test]
fn counter_blocks_identical_across_bench_runs() {
    // The tentpole determinism contract, as a property over random tiny
    // workloads: whatever the family/size/seed, re-running the same
    // workload reproduces the counter block bit-for-bit.
    let families = [FamilyKind::Darcy, FamilyKind::Poisson, FamilyKind::Thermal];
    check_msg(
        "bench counters are deterministic",
        Config { cases: 5, seed: 0xBE7C4 },
        |rng| {
            let mut m = tiny_manifest();
            let w = &mut m.workloads[0];
            w.family = families[rng.below(families.len())];
            w.unknowns = 64 + 16 * rng.below(4);
            w.count = 3 + rng.below(3);
            w.seed = rng.next_u64() % 1000;
            w.name = format!("prop-{}-n{}-s{}", w.family.label(), w.unknowns, w.seed);
            m.workloads[0].clone()
        },
        |w| {
            let a = run_engine(w, Engine::SkrRecycle, 0, 1).map_err(|e| e.to_string())?;
            let b = run_engine(w, Engine::SkrRecycle, 0, 1).map_err(|e| e.to_string())?;
            if a.counters != b.counters || a.total_iters != b.total_iters {
                return Err(format!("counter drift: {:?} vs {:?}", a.counters, b.counters));
            }
            Ok(())
        },
    );
}

#[test]
fn baseline_round_trips_through_disk_and_gate_passes_on_same_rev() {
    let m = tiny_manifest();
    let results = run_manifest(&m, |_| {}).unwrap();
    assert!(results[0].skr.stable && results[0].gmres.stable);

    // `--out` then `--check` on the same revision: zero counter drift.
    let path = unique_path("roundtrip");
    Baseline::new("samerev", &m, results).save(&path).unwrap();
    let base = Baseline::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(base.rev, "samerev");
    assert_eq!(base.runs, m.runs);

    let replay = run_manifest(&base.manifest(), |_| {}).unwrap();
    let regs = check(&base, &replay, 0.05, true);
    assert!(regs.is_empty(), "same-rev replay must pass the gate: {regs:?}");
}

#[test]
fn degraded_solver_fails_the_gate_and_healthy_one_beats_gmres() {
    let m = tiny_manifest();
    let w = &m.workloads[0];
    let good = run_workload(w, 0, 1).unwrap();

    // The paper's headline claim, on the Darcy workload: recycling does
    // strictly less Krylov work than the GMRES baseline.
    assert!(good.iters_speedup() > 1.0, "expected speedup > 1: {:?}", good.iters_speedup());
    assert!(good.skr.counters.recycle_installs() > 0);
    assert_eq!(good.gmres.counters.recycle_installs(), 0);

    let base = Baseline::new("good", &m, vec![good.clone()]);

    // Degraded solver: recycling silently disabled. Its measured behaviour
    // is exactly the GMRES arm — more matvecs, zero subspace installs —
    // and the gate must reject it.
    let mut degraded = good.clone();
    degraded.skr.counters = degraded.gmres.counters;
    degraded.skr.total_iters = degraded.gmres.total_iters;
    let regs = check(&base, &[degraded], 0.05, true);
    assert!(!regs.is_empty(), "recycling-disabled run must fail the gate");
    let all = regs.iter().map(|r| r.to_string()).collect::<Vec<_>>().join("\n");
    assert!(all.contains("matvecs"), "{all}");
    assert!(all.contains("recycling went inactive"), "{all}");
}
