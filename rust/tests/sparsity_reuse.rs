//! Property tests for the shared-sparsity matrix model (ISSUE 7 satellite):
//!
//! 1. `Sparsity::from_pattern` + `Csr::with_values` stamping is bit-for-bit
//!    equal to a `Csr::from_triplets` assembly on duplicate-free triplets.
//! 2. A cached symbolic preconditioner phase refactored onto perturbed
//!    values applies identically to a from-scratch build, for every kind.
//! 3. `solve_sequence` (shared workspace + cached symbolic phase) matches
//!    per-system fresh solves exactly, for both engines.

use skr::la::{Csr, Sparsity};
use skr::precond::{PrecondKind, Preconditioner};
use skr::solver::{gcrodr, gmres, solve_sequence, Engine, LinearSystem, Recycler, SolverConfig};
use skr::util::prng::Rng;
use skr::util::propcheck::{check_msg, Config};
use std::sync::Arc;

/// Random duplicate-free triplets: a guaranteed dominant diagonal plus a
/// sprinkle of off-diagonal entries, in shuffled insertion order.
fn random_triplets(rng: &mut Rng) -> (usize, Vec<(usize, usize, f64)>) {
    let n = 5 + rng.below(25);
    let mut trips = Vec::new();
    for i in 0..n {
        trips.push((i, i, 4.0 + rng.uniform()));
        for j in 0..n {
            if j != i && rng.uniform() < 0.15 {
                trips.push((i, j, rng.normal()));
            }
        }
    }
    rng.shuffle(&mut trips);
    (n, trips)
}

#[test]
fn stamping_matches_from_triplets_bitwise() {
    check_msg(
        "with_values == from_triplets",
        Config { cases: 64, seed: 0x5A11 },
        random_triplets,
        |(n, trips)| {
            let m1 = Csr::from_triplets(*n, *n, trips);
            let pairs: Vec<(usize, usize)> = trips.iter().map(|&(r, c, _)| (r, c)).collect();
            let sp = Arc::new(Sparsity::from_pattern(*n, *n, &pairs));
            let mut vals = vec![0.0; sp.nnz()];
            for &(r, c, v) in trips {
                vals[sp.pos(r, c).ok_or_else(|| format!("missing ({r},{c})"))?] = v;
            }
            let m2 = Csr::with_values(sp, vals).map_err(|e| e.to_string())?;
            if **m1.sparsity() != **m2.sparsity() {
                return Err("patterns differ".into());
            }
            for (i, (a, b)) in m1.values().iter().zip(m2.values()).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("value {i}: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

/// Symmetric, diagonally dominant tridiagonal base — valid input for every
/// preconditioner kind, including IC(0).
fn lap1d(n: usize) -> Csr {
    let mut trips = Vec::new();
    for i in 0..n {
        trips.push((i, i, 4.0));
        if i > 0 {
            trips.push((i, i - 1, -1.0));
        }
        if i + 1 < n {
            trips.push((i, i + 1, -1.0));
        }
    }
    Csr::from_triplets(n, n, &trips)
}

#[test]
fn symbolic_reuse_applies_identically_across_perturbations() {
    let n = 60;
    let base = lap1d(n);
    let sp = base.sparsity().clone();
    // One symbolic phase per kind, built once and reused for all 50 cases.
    let symbolics: Vec<(PrecondKind, skr::precond::SymbolicPrecond)> =
        PrecondKind::ALL.iter().map(|k| (*k, k.symbolic(&sp).unwrap())).collect();
    let r_in: Vec<f64> = Rng::new(99).normals(n);
    check_msg(
        "cached symbolic == fresh build",
        Config { cases: 50, seed: 0xD1A6 },
        |rng| {
            // Perturb the diagonal only: keeps symmetry (ICC's main path)
            // and diagonal dominance, and exercises a fresh value vector.
            let mut vals = base.values().to_vec();
            for i in 0..n {
                vals[base.sparsity().diag_pos(i).unwrap()] = 4.0 + rng.uniform();
            }
            vals
        },
        |vals| {
            let a = Csr::with_values(sp.clone(), vals.clone()).map_err(|e| e.to_string())?;
            for (kind, sym) in &symbolics {
                let fresh = kind.build(&a).map_err(|e| e.to_string())?;
                let cached = sym.refactor(&a).map_err(|e| e.to_string())?;
                let mut z1 = vec![0.0; n];
                let mut z2 = vec![0.0; n];
                fresh.apply(&r_in, &mut z1);
                cached.apply(&r_in, &mut z2);
                for (i, (u, v)) in z1.iter().zip(&z2).enumerate() {
                    if u.to_bits() != v.to_bits() {
                        return Err(format!("{kind:?} apply[{i}]: {u} vs {v}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A mildly nonsymmetric sequence sharing one `Arc<Sparsity>` — values
/// scaled per system, right-hand sides random.
fn shared_sequence(n: usize, count: usize) -> Vec<LinearSystem> {
    let mut trips = Vec::new();
    for i in 0..n {
        trips.push((i, i, 4.0));
        if i > 0 {
            trips.push((i, i - 1, -1.2));
        }
        if i + 1 < n {
            trips.push((i, i + 1, -0.8));
        }
    }
    let base = Csr::from_triplets(n, n, &trips);
    let sp = base.sparsity().clone();
    let mut rng = Rng::new(0xBEEF);
    (0..count)
        .map(|i| {
            let mut vals = base.values().to_vec();
            for v in &mut vals {
                *v *= 1.0 + 0.03 * i as f64;
            }
            let a = Csr::with_values(sp.clone(), vals).unwrap();
            LinearSystem { id: i, a, b: rng.normals(n), params: vec![i as f64] }
        })
        .collect()
}

#[test]
fn solve_sequence_matches_fresh_per_system_solves() {
    let systems = shared_sequence(150, 4);
    let cfg = SolverConfig::default().with_tol(1e-9).with_m(20).with_k(5);
    for engine in [Engine::Gmres, Engine::SkrRecycle] {
        let pooled = solve_sequence(&systems, engine, PrecondKind::Ilu, &cfg).unwrap();
        // Fresh baseline: per-system preconditioner build and solver-internal
        // scratch; the recycler is shared because recycling is the algorithm,
        // not a cache.
        let mut rec = Recycler::new();
        for (i, sys) in systems.iter().enumerate() {
            let p = PrecondKind::Ilu.build(&sys.a).unwrap();
            let mut x = vec![0.0; sys.b.len()];
            let s = match engine {
                Engine::Gmres => gmres(&sys.a, &sys.b, &mut x, p.as_ref(), &cfg),
                Engine::SkrRecycle => gcrodr(&sys.a, &sys.b, &mut x, p.as_ref(), &cfg, &mut rec),
            };
            let (px, ps) = &pooled[i];
            assert_eq!(s.iters, ps.iters, "{engine:?} sys {i}");
            assert_eq!(s.stop, ps.stop, "{engine:?} sys {i}");
            assert_eq!(
                s.rel_residual.to_bits(),
                ps.rel_residual.to_bits(),
                "{engine:?} sys {i} residual"
            );
            for (j, (u, v)) in x.iter().zip(px).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "{engine:?} sys {i} x[{j}]: {u} vs {v}");
            }
        }
    }
}
