//! End-to-end tests for the distributed generation subsystem: a real
//! coordinator on an ephemeral port, real workers joining over HTTP, and
//! byte-level comparison against the single-node pipeline.

use skr::coordinator::Pipeline;
use skr::dist::{coordinate_bound, work, CoordinateConfig, DistSummary, LeaseConfig, WorkerConfig};
use skr::service::http::request;
use skr::service::JobSpec;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread::JoinHandle;

fn unique_dir(tag: &str) -> PathBuf {
    static COUNTER: AtomicUsize = AtomicUsize::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("skr_dist_{tag}_{}_{n}", std::process::id()))
}

fn small_spec(seed: u64, count: usize, out: &std::path::Path) -> JobSpec {
    JobSpec {
        family: "darcy".into(),
        unknowns: 100,
        count,
        engine: "skr".into(),
        precond: "jacobi".into(),
        sort: "greedy".into(),
        threads: 2,
        seed,
        out: Some(out.display().to_string()),
        ..JobSpec::default()
    }
}

/// Run the reference single-node pipeline (`skr generate --threads 2`) for
/// the same spec into `dir` and return its metrics.
fn reference_run(spec: &JobSpec, dir: &std::path::Path) -> skr::coordinator::metrics::RunMetrics {
    let mut cfg = spec.to_config().unwrap();
    cfg.out_dir = Some(dir.to_path_buf());
    Pipeline::new(cfg).run().unwrap().metrics
}

/// Bind an ephemeral port and launch the coordinator on a thread.
fn spawn_coordinator(cfg: CoordinateConfig) -> (String, JoinHandle<anyhow::Result<DistSummary>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || coordinate_bound(&cfg, listener));
    (addr, handle)
}

fn spawn_worker(addr: &str, name: &str) -> JoinHandle<anyhow::Result<()>> {
    let cfg = WorkerConfig { join: addr.to_string(), name: name.to_string() };
    std::thread::spawn(move || work(&cfg))
}

fn assert_datasets_byte_identical(a: &std::path::Path, b: &std::path::Path) {
    for file in ["inputs.npy", "solutions.npy", "meta.json"] {
        let got = std::fs::read(a.join(file)).unwrap();
        let want = std::fs::read(b.join(file)).unwrap();
        assert_eq!(got, want, "{file} differs between distributed and single-node runs");
    }
}

#[test]
fn two_workers_match_single_node_byte_for_byte() {
    let dist_dir = unique_dir("two_out");
    let ref_dir = unique_dir("two_ref");
    let spec = small_spec(3, 12, &dist_dir);
    let ref_metrics = reference_run(&spec, &ref_dir);

    let (addr, coord) = spawn_coordinator(CoordinateConfig {
        bind: String::new(), // unused: the listener is pre-bound
        spec,
        shards: 2,
        lease: LeaseConfig::default(),
        linger_ms: 1_000,
    });
    let wa = spawn_worker(&addr, "wa");
    let wb = spawn_worker(&addr, "wb");
    wa.join().unwrap().unwrap();
    wb.join().unwrap().unwrap();
    let summary = coord.join().unwrap().unwrap();

    // A clean run: one grant per shard, nothing expired or duplicated.
    assert_eq!(summary.systems, 12);
    assert_eq!(summary.shards, 2);
    assert_eq!(summary.granted, 2, "{summary:?}");
    assert_eq!(summary.expired, 0);
    assert_eq!(summary.duplicates, 0);
    assert!(!summary.degraded);
    assert!(summary.bytes_merged > 0);
    assert_eq!(summary.dataset.as_ref().unwrap().count, 12);

    // The merged dataset is byte-identical to `generate --threads 2` …
    assert_datasets_byte_identical(&dist_dir, &ref_dir);
    // … and so are the aggregates: summed op counters match *exactly*
    // (u64), as do the iteration totals and the worst-residual bits.
    assert_eq!(summary.metrics.counters, ref_metrics.counters);
    assert_eq!(summary.metrics.total_iters, ref_metrics.total_iters);
    assert_eq!(summary.metrics.max_iter_hits, ref_metrics.max_iter_hits);
    assert_eq!(
        summary.metrics.rel_residual_worst.to_bits(),
        ref_metrics.rel_residual_worst.to_bits()
    );
    assert_eq!(summary.metrics.sparsity_reuse, ref_metrics.sparsity_reuse);
    assert_eq!(summary.metrics.symbolic_reuse, ref_metrics.symbolic_reuse);
    assert_eq!(summary.metrics.workspace_reuse, ref_metrics.workspace_reuse);
    // Per-shard spans landed on the timeline next to the plan stages.
    let names: Vec<&str> = summary.spans.iter().map(|s| s.name.as_str()).collect();
    for want in ["gen", "sort", "shard", "dist/shard0", "dist/shard1"] {
        assert!(names.contains(&want), "missing {want} span in {names:?}");
    }

    let _ = std::fs::remove_dir_all(&dist_dir);
    let _ = std::fs::remove_dir_all(&ref_dir);
}

#[test]
fn abandoned_lease_expires_and_is_regranted() {
    let dist_dir = unique_dir("exp_out");
    let ref_dir = unique_dir("exp_ref");
    let spec = small_spec(11, 8, &dist_dir);
    let ref_metrics = reference_run(&spec, &ref_dir);

    let (addr, coord) = spawn_coordinator(CoordinateConfig {
        bind: String::new(),
        spec,
        shards: 2,
        lease: LeaseConfig { lease_ms: 400, max_attempts: 5, backoff_ms: 50 },
        linger_ms: 1_000,
    });

    // A rogue client grabs a lease and vanishes: no heartbeat, no result —
    // the dead-worker scenario.
    let (status, plan) = request(&addr, "GET", "/plan", None).unwrap();
    assert_eq!(status, 200);
    assert!(plan.contains("\"version\""), "{plan}");
    let (status, body) =
        request(&addr, "POST", "/lease", Some(r#"{"worker":"rogue"}"#)).unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"grant\":\"lease\""), "{body}");
    let (status, metrics) = request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(metrics.contains("skr_dist_leases_granted_total 1"), "{metrics}");
    assert!(metrics.contains("skr_dist_shards_done 0"), "{metrics}");

    // One live worker must still complete the whole run: it picks up the
    // free shard immediately and the abandoned one after its lease lapses.
    spawn_worker(&addr, "steady").join().unwrap().unwrap();
    let summary = coord.join().unwrap().unwrap();

    assert!(summary.expired >= 1, "abandoned lease never expired: {summary:?}");
    assert!(summary.granted >= 3, "{summary:?}");
    assert!(!summary.degraded, "{summary:?}");
    assert_eq!(summary.systems, 8);

    // The retried shard re-solved to the very same bytes.
    assert_datasets_byte_identical(&dist_dir, &ref_dir);
    assert_eq!(summary.metrics.counters, ref_metrics.counters);
    assert_eq!(summary.metrics.total_iters, ref_metrics.total_iters);

    let _ = std::fs::remove_dir_all(&dist_dir);
    let _ = std::fs::remove_dir_all(&ref_dir);
}
