//! Cross-module property and invariant tests over the public API: linear
//! algebra identities, solver correctness invariants, sorter permutation
//! properties, preconditioner algebra, PDE family determinism, and dataset
//! round-trips. These complement the per-module unit tests.

#![allow(clippy::field_reassign_with_default)]
use skr::coordinator::sorter::{chain_cost, dist2, sort_order, SortStrategy};
use skr::coordinator::{Pipeline, PipelineConfig};
use skr::la::dense::Mat;
use skr::la::{axpy, dot, norm2, Csr};
use skr::pde::{generate, FamilyKind};
use skr::precond::PrecondKind;
use skr::solver::{gcrodr, gmres, Engine, Recycler, SolverConfig};
use skr::util::npy::{self, NpyArray};
use skr::util::prng::Rng;
use skr::util::propcheck::{check_msg, Config};

// ---------------------------------------------------------------------------
// Linear-algebra identities (propcheck).
// ---------------------------------------------------------------------------

fn random_mat(rng: &mut Rng, nrows: usize, ncols: usize) -> Mat {
    let mut m = Mat::zeros(nrows, ncols);
    for v in &mut m.data {
        *v = rng.normal();
    }
    m
}

#[test]
fn qr_reconstructs_and_q_is_orthonormal() {
    check_msg(
        "qr identity",
        Config { cases: 40, seed: 0xA11CE },
        |rng| {
            let nrows = 3 + (rng.next_u64() % 12) as usize;
            let ncols = 1 + (rng.next_u64() % nrows as u64) as usize;
            random_mat(rng, nrows, ncols)
        },
        |a| {
            let (q, r) = a.qr_thin();
            // QᵀQ = I
            let qtq = q.transpose().matmul(&q);
            for i in 0..qtq.nrows {
                for j in 0..qtq.ncols {
                    let want = if i == j { 1.0 } else { 0.0 };
                    if (qtq[(i, j)] - want).abs() > 1e-10 {
                        return Err(format!("QᵀQ[{i},{j}] = {}", qtq[(i, j)]));
                    }
                }
            }
            // QR = A
            let qr = q.matmul(&r);
            for i in 0..a.nrows {
                for j in 0..a.ncols {
                    if (qr[(i, j)] - a[(i, j)]).abs() > 1e-10 {
                        return Err(format!("QR≠A at ({i},{j})"));
                    }
                }
            }
            // R upper triangular
            for j in 0..r.ncols {
                for i in (j + 1)..r.nrows {
                    if r[(i, j)].abs() > 1e-12 {
                        return Err(format!("R not triangular at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn lstsq_residual_is_orthogonal_to_range() {
    check_msg(
        "lstsq normal equations",
        Config { cases: 40, seed: 0xB0B },
        |rng| {
            let nrows = 4 + (rng.next_u64() % 10) as usize;
            let ncols = 1 + (rng.next_u64() % (nrows as u64 - 1)) as usize;
            let a = random_mat(rng, nrows, ncols);
            let b = rng.normals(nrows);
            (a, b)
        },
        |(a, b)| {
            let y = a.lstsq(b).map_err(|e| e.to_string())?;
            let ay = a.matvec(&y);
            let r: Vec<f64> = b.iter().zip(&ay).map(|(bi, ai)| bi - ai).collect();
            // Aᵀ r = 0 for the least-squares minimiser.
            let atr = a.matvec_t(&r);
            for (j, v) in atr.iter().enumerate() {
                if v.abs() > 1e-8 {
                    return Err(format!("Aᵀr[{j}] = {v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn csr_matvec_agrees_with_dense() {
    check_msg(
        "csr vs dense matvec",
        Config { cases: 40, seed: 0xCAFE },
        |rng| {
            let n = 2 + (rng.next_u64() % 20) as usize;
            let mut trips = Vec::new();
            let mut dense = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    if rng.next_u64() % 4 == 0 {
                        let v = rng.normal();
                        trips.push((i, j, v));
                        dense[(i, j)] = v;
                    }
                }
            }
            // Guarantee a nonzero diagonal so the matrix is usable elsewhere.
            for i in 0..n {
                trips.push((i, i, 1.0));
                dense[(i, i)] += 1.0;
            }
            let x = rng.normals(n);
            (Csr::from_triplets(n, n, &trips), dense, x)
        },
        |(a, dense, x)| {
            let y1 = a.matvec(x);
            let y2 = dense.matvec(x);
            for i in 0..y1.len() {
                if (y1[i] - y2[i]).abs() > 1e-10 {
                    return Err(format!("row {i}: {} vs {}", y1[i], y2[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn csr_transpose_is_involutive_and_adjoint() {
    check_msg(
        "transpose adjoint",
        Config { cases: 30, seed: 0xD00D },
        |rng| {
            let n = 3 + (rng.next_u64() % 15) as usize;
            let mut trips = Vec::new();
            for i in 0..n {
                trips.push((i, i, 1.0 + rng.normal().abs()));
                let j = (rng.next_u64() % n as u64) as usize;
                trips.push((i, j, rng.normal()));
            }
            (Csr::from_triplets(n, n, &trips), rng.normals(n), rng.normals(n))
        },
        |(a, x, y)| {
            let at = a.transpose();
            // ⟨Ax, y⟩ = ⟨x, Aᵀy⟩
            let lhs = dot(&a.matvec(x), y);
            let rhs = dot(x, &at.matvec(y));
            if (lhs - rhs).abs() > 1e-9 * (1.0 + lhs.abs()) {
                return Err(format!("{lhs} vs {rhs}"));
            }
            // (Aᵀ)ᵀ = A as an operator
            let back = at.transpose();
            let d: f64 = a
                .matvec(x)
                .iter()
                .zip(back.matvec(x))
                .map(|(u, v)| (u - v).abs())
                .fold(0.0, f64::max);
            if d > 1e-12 {
                return Err(format!("involution error {d}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Preconditioner algebra.
// ---------------------------------------------------------------------------

/// Random strictly diagonally dominant sparse matrix (all preconditioners
/// are well-defined on it).
fn random_sdd(rng: &mut Rng, n: usize) -> Csr {
    let mut trips = Vec::new();
    for i in 0..n {
        let mut offsum = 0.0;
        for _ in 0..3 {
            let j = (rng.next_u64() % n as u64) as usize;
            if j != i {
                let v = 0.5 * rng.normal();
                offsum += v.abs();
                trips.push((i, j, v));
            }
        }
        trips.push((i, i, offsum + 1.0 + rng.normal().abs()));
    }
    Csr::from_triplets(n, n, &trips)
}

#[test]
fn preconditioners_are_linear_operators() {
    check_msg(
        "precond linearity",
        Config { cases: 10, seed: 0x11111 },
        |rng| {
            let n = 16 + (rng.next_u64() % 40) as usize;
            (random_sdd(rng, n), rng.normals(n), rng.normals(n), rng.normal())
        },
        |(a, u, v, alpha)| {
            let n = u.len();
            for kind in PrecondKind::ALL {
                let p = kind.build(a).map_err(|e| e.to_string())?;
                let mut pu = vec![0.0; n];
                let mut pv = vec![0.0; n];
                let mut pw = vec![0.0; n];
                p.apply(u, &mut pu);
                p.apply(v, &mut pv);
                let w: Vec<f64> = u.iter().zip(v).map(|(a, b)| a + alpha * b).collect();
                p.apply(&w, &mut pw);
                for i in 0..n {
                    let want = pu[i] + alpha * pv[i];
                    let scale = 1.0 + want.abs();
                    if (pw[i] - want).abs() > 1e-9 * scale {
                        return Err(format!("{kind:?} not linear at {i}: {} vs {want}", pw[i]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn jacobi_inverts_pure_diagonal_exactly() {
    let n = 24;
    let trips: Vec<(usize, usize, f64)> = (0..n).map(|i| (i, i, (i + 1) as f64)).collect();
    let a = Csr::from_triplets(n, n, &trips);
    for kind in [PrecondKind::Jacobi, PrecondKind::BJacobi, PrecondKind::Ilu, PrecondKind::Icc] {
        let p = kind.build(&a).unwrap();
        let r: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
        let mut z = vec![0.0; n];
        p.apply(&r, &mut z);
        for (i, v) in z.iter().enumerate() {
            assert!((v - 1.0).abs() < 1e-12, "{kind:?} z[{i}] = {v}");
        }
    }
}

#[test]
fn preconditioned_gmres_converges_for_every_kind() {
    let mut rng = Rng::new(0x5EED5);
    let a = random_sdd(&mut rng, 120);
    let xtrue = rng.normals(120);
    let b = a.matvec(&xtrue);
    for kind in PrecondKind::ALL {
        let p = kind.build(&a).unwrap();
        let mut x = vec![0.0; 120];
        let s = gmres(&a, &b, &mut x, p.as_ref(), &SolverConfig::default().with_tol(1e-10));
        assert!(s.converged(), "{kind:?} {s:?}");
        let err: f64 = x.iter().zip(&xtrue).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        assert!(err < 1e-6, "{kind:?} err {err}");
    }
}

// ---------------------------------------------------------------------------
// Solver invariants.
// ---------------------------------------------------------------------------

#[test]
fn gmres_final_residual_matches_reported() {
    check_msg(
        "gmres residual honesty",
        Config { cases: 12, seed: 0x77777 },
        |rng| {
            let n = 30 + (rng.next_u64() % 80) as usize;
            let a = random_sdd(rng, n);
            let b = rng.normals(n);
            (a, b)
        },
        |(a, b)| {
            let mut x = vec![0.0; b.len()];
            let s = gmres(a, b, &mut x, &skr::precond::Identity, &SolverConfig::default().with_tol(1e-9));
            let mut r = b.clone();
            let ax = a.matvec(&x);
            axpy(-1.0, &ax, &mut r);
            let rel = norm2(&r) / norm2(b).max(1e-300);
            if (rel - s.rel_residual).abs() > 1e-7 {
                return Err(format!("reported {} vs true {rel}", s.rel_residual));
            }
            if s.converged() && rel > 1e-8 {
                return Err(format!("claimed convergence at rel {rel}"));
            }
            Ok(())
        },
    );
}

#[test]
fn gcrodr_equals_gmres_solution_on_one_system() {
    check_msg(
        "gcrodr correctness",
        Config { cases: 10, seed: 0x88888 },
        |rng| {
            let n = 40 + (rng.next_u64() % 60) as usize;
            let a = random_sdd(rng, n);
            let b = rng.normals(n);
            (a, b)
        },
        |(a, b)| {
            let cfg = SolverConfig::default().with_tol(1e-11);
            let mut x1 = vec![0.0; b.len()];
            gmres(a, b, &mut x1, &skr::precond::Identity, &cfg);
            let mut x2 = vec![0.0; b.len()];
            let mut rec = Recycler::new();
            gcrodr(a, b, &mut x2, &skr::precond::Identity, &cfg, &mut rec);
            let d: f64 = x1.iter().zip(&x2).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
            let scale = x1.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
            if d > 1e-7 * scale {
                return Err(format!("solutions differ by {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn gcrodr_early_exit_never_overshoots_cycle() {
    // With a loose tolerance the solver must stop mid-cycle: total iterations
    // strictly below one restart length on an easy system.
    let mut rng = Rng::new(0x99999);
    let a = random_sdd(&mut rng, 200);
    let xtrue = rng.normals(200);
    let b = a.matvec(&xtrue);
    let cfg = SolverConfig::default().with_tol(1e-1).with_m(30).with_k(10);
    let mut rec = Recycler::new();
    let mut x = vec![0.0; 200];
    let s = gcrodr(&a, &b, &mut x, &skr::precond::Identity, &cfg, &mut rec);
    assert!(s.converged());
    assert!(s.iters < 30, "early exit failed: {} iters", s.iters);
}

#[test]
fn recycler_fast_path_skips_reseed_on_identical_operator() {
    let mut rng = Rng::new(0xAAAAA);
    let a = random_sdd(&mut rng, 150);
    let cfg = SolverConfig::default().with_tol(1e-10).with_m(25).with_k(6);
    let mut rec = Recycler::new();
    let b1 = rng.normals(150);
    let mut x = vec![0.0; 150];
    gcrodr(&a, &b1, &mut x, &skr::precond::Identity, &cfg, &mut rec);
    // Same operator, new rhs: warm solve.
    let b2 = rng.normals(150);
    let mut x2 = vec![0.0; 150];
    let s_same = gcrodr(&a, &b2, &mut x2, &skr::precond::Identity, &cfg, &mut rec);
    // Perturbed operator forces the k reseed applies.
    let a2 = a.add_diag(1e-6);
    let mut rec2 = Recycler::new();
    let mut x3 = vec![0.0; 150];
    gcrodr(&a, &b1, &mut x3, &skr::precond::Identity, &cfg, &mut rec2);
    let mut x4 = vec![0.0; 150];
    let s_diff = gcrodr(&a2, &b2, &mut x4, &skr::precond::Identity, &cfg, &mut rec2);
    assert!(s_same.converged() && s_diff.converged());
    // Both must solve correctly; the identical-operator path does not pay
    // the reseed so it can never need *more* iterations.
    assert!(
        s_same.iters <= s_diff.iters,
        "fast path {} vs reseed path {}",
        s_same.iters,
        s_diff.iters
    );
}

#[test]
fn recycler_survives_dimension_change() {
    let mut rng = Rng::new(0xBBBBB);
    let a1 = random_sdd(&mut rng, 90);
    let b1 = rng.normals(90);
    let cfg = SolverConfig::default().with_tol(1e-9);
    let mut rec = Recycler::new();
    let mut x1 = vec![0.0; 90];
    let s1 = gcrodr(&a1, &b1, &mut x1, &skr::precond::Identity, &cfg, &mut rec);
    assert!(s1.converged());
    assert!(rec.dim() > 0);
    // Different-sized system with the same recycler must not panic and must
    // still converge (the stale space is dropped).
    let a2 = random_sdd(&mut rng, 140);
    let b2 = rng.normals(140);
    let mut x2 = vec![0.0; 140];
    let s2 = gcrodr(&a2, &b2, &mut x2, &skr::precond::Identity, &cfg, &mut rec);
    assert!(s2.converged(), "{s2:?}");
}

#[test]
fn trace_is_recorded_and_monotone_at_cycle_ends() {
    let mut rng = Rng::new(0xCCCCC);
    let a = random_sdd(&mut rng, 300);
    let b = rng.normals(300);
    let cfg = SolverConfig::default().with_tol(1e-10).with_trace(true);
    let mut x = vec![0.0; 300];
    let s = gmres(&a, &b, &mut x, &skr::precond::Identity, &cfg);
    assert!(s.trace.len() >= 2);
    assert_eq!(s.trace[0].0, 0);
    for w in s.trace.windows(2) {
        assert!(w[1].0 > w[0].0, "iters must increase: {:?}", s.trace);
        // GMRES minimises the residual over a growing space: restart-boundary
        // residuals never increase.
        assert!(w[1].1 <= w[0].1 * (1.0 + 1e-9), "residual went up: {:?}", s.trace);
    }
}

// ---------------------------------------------------------------------------
// Sorter invariants.
// ---------------------------------------------------------------------------

fn random_params(rng: &mut Rng, count: usize, dim: usize) -> Vec<Vec<f64>> {
    (0..count).map(|_| rng.normals(dim)).collect()
}

#[test]
fn every_strategy_returns_a_permutation() {
    check_msg(
        "sort permutation",
        Config { cases: 20, seed: 0xDDDDD },
        |rng| {
            let count = 1 + (rng.next_u64() % 40) as usize;
            let dim = 1 + (rng.next_u64() % 8) as usize;
            random_params(rng, count, dim)
        },
        |params| {
            for strat in [
                SortStrategy::None,
                SortStrategy::Greedy,
                SortStrategy::GroupedGreedy { group_size: 8 },
                SortStrategy::Hilbert,
                SortStrategy::Shuffle,
            ] {
                let order = sort_order(params, strat, 7);
                let mut seen = vec![false; params.len()];
                if order.len() != params.len() {
                    return Err(format!("{strat:?}: wrong length"));
                }
                for &i in &order {
                    if i >= params.len() || seen[i] {
                        return Err(format!("{strat:?}: not a permutation"));
                    }
                    seen[i] = true;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn greedy_chain_cost_never_worse_than_identity() {
    check_msg(
        "greedy improves chain cost",
        Config { cases: 20, seed: 0xEEEEE },
        |rng| random_params(rng, 30, 4),
        |params| {
            let id: Vec<usize> = (0..params.len()).collect();
            let greedy = sort_order(params, SortStrategy::Greedy, 0);
            let c_id = chain_cost(params, &id);
            let c_greedy = chain_cost(params, &greedy);
            if c_greedy > c_id * (1.0 + 1e-12) {
                return Err(format!("greedy {c_greedy} worse than identity {c_id}"));
            }
            Ok(())
        },
    );
}

#[test]
fn grouped_greedy_is_competitive_with_greedy() {
    // With group_size ≥ count the grouped variant runs a single greedy chain
    // (from a projection-chosen start instead of id 0): its tour cost must
    // be in the same ballpark as plain greedy and beat the identity order.
    let mut rng = Rng::new(3);
    let params = random_params(&mut rng, 40, 3);
    let id: Vec<usize> = (0..params.len()).collect();
    let greedy = sort_order(&params, SortStrategy::Greedy, 0);
    let grouped = sort_order(&params, SortStrategy::GroupedGreedy { group_size: 100 }, 0);
    let (c_id, c_g, c_gg) =
        (chain_cost(&params, &id), chain_cost(&params, &greedy), chain_cost(&params, &grouped));
    assert!(c_gg <= c_id, "grouped {c_gg} vs identity {c_id}");
    assert!(c_gg <= 2.0 * c_g, "grouped {c_gg} vs greedy {c_g}");
}

#[test]
fn dist2_is_a_metric_squared() {
    let mut rng = Rng::new(9);
    for _ in 0..50 {
        let a = rng.normals(6);
        let b = rng.normals(6);
        assert!((dist2(&a, &b) - dist2(&b, &a)).abs() < 1e-12);
        assert!(dist2(&a, &a) < 1e-24);
        assert!(dist2(&a, &b) >= 0.0);
    }
}

// ---------------------------------------------------------------------------
// PDE family invariants.
// ---------------------------------------------------------------------------

#[test]
fn families_are_deterministic_per_seed() {
    for fam in [FamilyKind::Darcy, FamilyKind::Thermal, FamilyKind::Poisson, FamilyKind::Helmholtz] {
        let f = fam.build(150);
        let s1 = generate(f.as_ref(), 3, 11).unwrap();
        let s2 = generate(f.as_ref(), 3, 11).unwrap();
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.b, b.b, "{fam:?} rhs differs");
            assert_eq!(a.params, b.params, "{fam:?} params differ");
            assert_eq!(a.a.values(), b.a.values(), "{fam:?} matrix differs");
        }
        // Different seed ⇒ different systems.
        let s3 = generate(f.as_ref(), 3, 12).unwrap();
        assert!(
            s1.iter().zip(&s3).any(|(a, b)| a.params != b.params),
            "{fam:?} ignores the seed"
        );
    }
}

#[test]
fn family_systems_are_square_and_match_unknowns() {
    for fam in [FamilyKind::Darcy, FamilyKind::Thermal, FamilyKind::Poisson, FamilyKind::Helmholtz] {
        let f = fam.build(200);
        let sys = &generate(f.as_ref(), 1, 5).unwrap()[0];
        assert_eq!(sys.a.nrows(), sys.a.ncols(), "{fam:?}");
        assert_eq!(sys.a.nrows(), sys.b.len(), "{fam:?}");
        assert_eq!(sys.a.nrows(), f.num_unknowns(), "{fam:?}");
        assert!(!sys.params.is_empty(), "{fam:?} has no sort key");
    }
}

#[test]
fn poisson_and_thermal_matrices_are_symmetric() {
    for fam in [FamilyKind::Poisson, FamilyKind::Thermal] {
        let f = fam.build(150);
        let sys = &generate(f.as_ref(), 1, 2).unwrap()[0];
        let at = sys.a.transpose();
        let d: f64 = sys
            .a
            .values()
            .iter()
            .zip(at.values())
            .map(|(u, v)| (u - v).abs())
            .fold(0.0, f64::max);
        let scale = sys.a.values().iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(d <= 1e-12 * scale, "{fam:?} asymmetry {d}");
    }
}

#[test]
fn all_families_solvable_to_tight_tolerance() {
    for fam in [FamilyKind::Darcy, FamilyKind::Thermal, FamilyKind::Poisson, FamilyKind::Helmholtz] {
        let f = fam.build(120);
        let sys = &generate(f.as_ref(), 1, 3).unwrap()[0];
        let p = PrecondKind::Ilu.build(&sys.a).unwrap();
        let mut x = vec![0.0; sys.b.len()];
        let s = gmres(&sys.a, &sys.b, &mut x, p.as_ref(), &SolverConfig::default().with_tol(1e-10));
        assert!(s.converged(), "{fam:?}: {s:?}");
    }
}

// ---------------------------------------------------------------------------
// Engine equivalence through the full pipeline.
// ---------------------------------------------------------------------------

#[test]
fn pipeline_engines_agree_on_solutions() {
    let dir_g = std::env::temp_dir().join("skr_inv_gmres");
    let dir_s = std::env::temp_dir().join("skr_inv_skr");
    for d in [&dir_g, &dir_s] {
        let _ = std::fs::remove_dir_all(d);
    }
    let mk = |engine, out: &std::path::Path| {
        let mut cfg = PipelineConfig::default();
        cfg.family = FamilyKind::Darcy;
        cfg.unknowns = 100;
        cfg.count = 8;
        cfg.engine = engine;
        cfg.precond = PrecondKind::Jacobi;
        cfg.solver.tol = 1e-10;
        cfg.threads = 1;
        cfg.seed = 21;
        cfg.out_dir = Some(out.to_path_buf());
        Pipeline::new(cfg).run().unwrap()
    };
    mk(Engine::Gmres, &dir_g);
    mk(Engine::SkrRecycle, &dir_s);
    let (_, sol_g, _) = skr::coordinator::dataset::load(&dir_g).unwrap();
    let (_, sol_s, _) = skr::coordinator::dataset::load(&dir_s).unwrap();
    assert_eq!(sol_g.shape, sol_s.shape);
    let scale = sol_g.data.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1e-30);
    let maxd = sol_g
        .data
        .iter()
        .zip(&sol_s.data)
        .map(|(u, v)| (u - v).abs())
        .fold(0.0, f64::max);
    assert!(maxd < 1e-5 * scale, "engines disagree: {maxd} (scale {scale})");
}

// ---------------------------------------------------------------------------
// npy round-trips.
// ---------------------------------------------------------------------------

#[test]
fn npy_roundtrip_preserves_shape_and_data() {
    check_msg(
        "npy roundtrip",
        Config { cases: 20, seed: 0xF00D },
        |rng| {
            let d0 = 1 + (rng.next_u64() % 5) as usize;
            let d1 = 1 + (rng.next_u64() % 7) as usize;
            let data = rng.normals(d0 * d1);
            (vec![d0, d1], data)
        },
        |(shape, data)| {
            let path = std::env::temp_dir().join(format!("skr_npy_{}.npy", data.len()));
            let arr = NpyArray::f64(shape.clone(), data.clone());
            npy::write(&path, &arr).map_err(|e| e.to_string())?;
            let back = npy::read(&path).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&path);
            if back.shape != *shape {
                return Err(format!("shape {:?} vs {:?}", back.shape, shape));
            }
            for (u, v) in back.data.iter().zip(data) {
                if (u - v).abs() > 0.0 {
                    return Err("data mismatch".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn npy_rejects_garbage() {
    let path = std::env::temp_dir().join("skr_npy_garbage.npy");
    std::fs::write(&path, b"this is not an npy file at all").unwrap();
    assert!(npy::read(&path).is_err());
    let _ = std::fs::remove_file(&path);
}
