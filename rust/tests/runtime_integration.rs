//! Integration: the full AOT bridge — python-lowered HLO artifacts load,
//! compile and execute on the rust PJRT client, the Adam train step reduces
//! the loss, and inference round-trips. Skips (with a notice) when
//! `artifacts/` has not been built.

use skr::runtime::{FnoRuntime, Manifest};
use skr::util::prng::Rng;

fn artifacts_ready() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

/// Synthetic learnable task matching the python-side test: y = low-pass(x).
fn lowpass_case(grid: usize, batch: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; batch * grid * grid];
    let mut y = vec![0.0f32; batch * grid * grid];
    for b in 0..batch {
        // Smooth random field: sum of a few low-frequency sinusoids.
        let a1 = rng.normal() as f32;
        let a2 = rng.normal() as f32;
        let p1 = rng.uniform() as f32 * 6.28;
        for r in 0..grid {
            for c in 0..grid {
                let (fr, fc) = (r as f32 / grid as f32, c as f32 / grid as f32);
                let v = a1 * (6.28 * fr + p1).sin() + a2 * (6.28 * fc).cos()
                    + 0.3 * (rng.normal() as f32);
                let idx = (b * grid + r) * grid + c;
                x[idx] = v;
                // Target: the smooth part only (denoising operator).
                y[idx] = a1 * (6.28 * fr + p1).sin() + a2 * (6.28 * fc).cos();
            }
        }
    }
    (x, y)
}

#[test]
fn train_step_reduces_loss_through_pjrt() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut fno = FnoRuntime::load(&Manifest::default_dir()).unwrap();
    let (grid, batch) = (fno.manifest.grid, fno.manifest.batch);
    let (x, y) = lowpass_case(grid, batch, 1);

    let first = fno.train_step(&x, &y).unwrap();
    assert!(first.is_finite(), "first loss {first}");
    let mut last = first;
    for _ in 0..30 {
        last = fno.train_step(&x, &y).unwrap();
    }
    assert!(last.is_finite());
    assert!(
        last < 0.7 * first,
        "loss did not drop: {first} -> {last}"
    );
    assert_eq!(fno.steps_done().unwrap(), 31.0);
}

#[test]
fn forward_is_deterministic_and_shaped() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let fno = FnoRuntime::load(&Manifest::default_dir()).unwrap();
    let n = fno.batch_elems();
    let x = vec![0.5f32; n];
    let p1 = fno.predict(&x).unwrap();
    let p2 = fno.predict(&x).unwrap();
    assert_eq!(p1.len(), n);
    assert_eq!(p1, p2);
    assert!(p1.iter().all(|v| v.is_finite()));
}
