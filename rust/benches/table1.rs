//! `cargo bench --bench table1` — regenerates the paper's Table 1 at bench
//! scale. (Custom harness: criterion is not available in the offline
//! registry; the harness prints the paper-style table and writes CSV.)
//! Pass `-- --full` for the paper's matrix sizes.

use skr::harness::table1;
use skr::util::args::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if let Err(e) = table1::run(&args) {
        eprintln!("bench table1 failed: {e:#}");
        std::process::exit(1);
    }
}
