//! `cargo bench --bench figures` — regenerates the data series behind the
//! paper's Figures 1, 4/5, 7/8, 9/10, 11, 12 and 13 (CSV under results/).

use skr::harness::figures;
use skr::util::args::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if let Err(e) = figures::run(&args) {
        eprintln!("bench figures failed: {e:#}");
        std::process::exit(1);
    }
}
