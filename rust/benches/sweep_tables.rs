//! `cargo bench --bench sweep_tables` — regenerates the paper's appendix
//! Tables 3–30 (per-family size × tolerance × preconditioner sweeps).
//! Default is a reduced grid; `-- --full` runs the paper's sizes.

use skr::harness::sweeps;
use skr::util::args::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if let Err(e) = sweeps::run(&args) {
        eprintln!("bench sweep_tables failed: {e:#}");
        std::process::exit(1);
    }
}
