//! `cargo bench --bench ablation` — regenerates the paper's Table 2
//! (sorting ablation with the δ-subspace metric).

use skr::harness::ablation;
use skr::util::args::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if let Err(e) = ablation::run(&args) {
        eprintln!("bench ablation failed: {e:#}");
        std::process::exit(1);
    }
}
