//! `cargo bench --bench micro` — micro-benchmarks of the solver hot paths
//! (SpMV, dot/axpy, MGS orthogonalization, preconditioner applies, one
//! GMRES/GCRO-DR cycle, small-eig). These drive the §Perf optimization loop
//! in EXPERIMENTS.md. Custom min-of-N harness (criterion unavailable
//! offline).

use skr::la::dense::Mat;
use skr::la::{dot, eig, Csr, Sparsity, ZMat};
use skr::pde::{generate, FamilyKind};
use skr::precond::PrecondKind;
use skr::solver::{gcrodr, gmres, Recycler, SolverConfig};
use skr::util::prng::Rng;
use skr::util::timer::best_of;

fn report(name: &str, work: &str, secs: f64) {
    println!("{name:<28} {:>12.3} µs   {work}", secs * 1e6);
}

fn main() {
    let n = 10_000;
    let fam = FamilyKind::Darcy.build(n);
    let sys = &generate(fam.as_ref(), 1, 7).unwrap()[0];
    let a: &Csr = &sys.a;
    let mut rng = Rng::new(1);
    let x = rng.normals(n);
    let mut y = vec![0.0; n];

    // --- BLAS-1/SpMV kernels ------------------------------------------------
    let (_, t) = best_of(200, || a.matvec_into(&x, &mut y));
    report("spmv 10k (5-pt)", &format!("{} nnz", a.nnz()), t);

    let x2 = rng.normals(n);
    let (_, t) = best_of(500, || dot(&x, &x2));
    report("dot 10k", "", t);

    let mut w = rng.normals(n);
    let basis: Vec<Vec<f64>> = (0..30).map(|_| rng.normals(n)).collect();
    let (_, t) = best_of(50, || {
        let mut ww = w.clone();
        skr::la::ortho::cgs2_orthogonalize(&mut ww, &basis);
    });
    w[0] += 0.0;
    report("cgs2 vs 30 basis @10k", "", t);

    // --- assembly: fresh triplets vs stamping onto a shared pattern -----------
    {
        let side = (n as f64).sqrt() as usize;
        let mut trips = Vec::with_capacity(5 * n);
        for i in 0..side {
            for j in 0..side {
                let row = i * side + j;
                trips.push((row, row, 4.0));
                if i > 0 {
                    trips.push((row, row - side, -1.0));
                }
                if i + 1 < side {
                    trips.push((row, row + side, -1.0));
                }
                if j > 0 {
                    trips.push((row, row - 1, -1.0));
                }
                if j + 1 < side {
                    trips.push((row, row + 1, -1.0));
                }
            }
        }
        let (_, t) = best_of(20, || {
            let m = Csr::from_triplets(side * side, side * side, &trips);
            std::hint::black_box(m.nnz());
        });
        report("assemble from_triplets 10k", &format!("{} trips", trips.len()), t);

        let pairs: Vec<(usize, usize)> = trips.iter().map(|&(r, c, _)| (r, c)).collect();
        let sp = std::sync::Arc::new(Sparsity::from_pattern(side * side, side * side, &pairs));
        let stamped: Vec<f64> = {
            let m = Csr::from_triplets(side * side, side * side, &trips);
            m.values().to_vec()
        };
        let (_, t) = best_of(20, || {
            let m = Csr::with_values(sp.clone(), stamped.clone()).unwrap();
            std::hint::black_box(m.nnz());
        });
        report("assemble with_values 10k", &format!("{} nnz", sp.nnz()), t);
    }

    // --- preconditioner applies ----------------------------------------------
    for kind in [PrecondKind::Jacobi, PrecondKind::Sor, PrecondKind::Ilu, PrecondKind::Asm] {
        let p = kind.build(a).unwrap();
        let (_, t) = best_of(100, || p.apply(&x, &mut y));
        report(&format!("precond {} @10k", kind.label()), "", t);
    }

    // --- small dense eig (the GCRO-DR per-cycle cost) -------------------------
    for m in [20usize, 30, 40] {
        let mut mm = Mat::zeros(m, m);
        let mut r2 = Rng::new(2);
        for v in &mut mm.data {
            *v = r2.normal();
        }
        let z = ZMat::from_real(&mm);
        let (_, t) = best_of(10, || {
            let _ = eig::eig(&z).unwrap();
        });
        report(&format!("complex eig {m}x{m}"), "", t);
    }

    // --- full solves -----------------------------------------------------------
    let cfg = SolverConfig::default().with_tol(1e-6);
    let p = PrecondKind::Jacobi.build(a).unwrap();
    let (_, t) = best_of(3, || {
        let mut xx = vec![0.0; n];
        gmres(a, &sys.b, &mut xx, p.as_ref(), &cfg);
    });
    report("gmres darcy 10k @1e-6", "", t);

    let (_, t) = best_of(3, || {
        let mut xx = vec![0.0; n];
        let mut rec = Recycler::new();
        gcrodr(a, &sys.b, &mut xx, p.as_ref(), &cfg, &mut rec);
    });
    report("gcrodr cold darcy 10k", "", t);

    // Warm recycle: measure the second solve of an identical system.
    let mut rec = Recycler::new();
    let mut xx = vec![0.0; n];
    gcrodr(a, &sys.b, &mut xx, p.as_ref(), &cfg, &mut rec);
    let (_, t) = best_of(3, || {
        let mut xw = vec![0.0; n];
        // NOTE: clone the recycler so each reading starts from the same state.
        let mut rc = rec.clone();
        gcrodr(a, &sys.b, &mut xw, p.as_ref(), &cfg, &mut rc);
    });
    report("gcrodr warm darcy 10k", "", t);
}
