//! `cargo bench --bench parallel` — regenerates the paper's Tables 31/32
//! (threaded and block parallel variants).

use skr::harness::parallel;
use skr::util::args::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if let Err(e) = parallel::run(&args) {
        eprintln!("bench parallel failed: {e:#}");
        std::process::exit(1);
    }
}
