//! L5 distributed generation — `skr coordinate` / `skr work`.
//!
//! One coordinator plans a run exactly like single-node `skr generate`
//! (parameter pass → similarity sort → contiguous shards, see
//! [`crate::coordinator::RunPlan`]) and serves shard **leases** over the
//! same HTTP/JSON framing as `skr serve`; any number of workers join, pull
//! leases, solve their shards with per-shard Krylov recycling, and stream
//! the solutions back.
//!
//! | Method & path             | Body → response                            |
//! |---------------------------|--------------------------------------------|
//! | `GET /plan`               | run spec + shard layout + protocol version |
//! | `POST /lease`             | `{worker}` → lease / wait / finished       |
//! | `POST /heartbeat`         | `{shard, attempt, worker}` → `{ok}`        |
//! | `POST /shards/:id/result` | shard result → `{disposition}`             |
//! | `GET /metrics`            | Prometheus text (`skr_dist_*` + run)       |
//! | `GET /healthz`            | liveness + run completion                  |
//!
//! **Fault tolerance.** Leases expire unless heartbeats renew them; an
//! expired or failed shard is requeued with exponential backoff and
//! re-granted (bounded attempts — exceeding the budget flags the run
//! *degraded* but does not abort it). Duplicate and stale results are
//! rejected instead of merged twice ([`crate::coordinator::dataset`]'s
//! double-fill guard backstops this at the writer).
//!
//! **Bit-identity.** Each shard is a contiguous slice of the sorted order,
//! solved sequentially from fresh recycling state — exactly what one
//! single-node worker thread does — and every payload that must survive
//! the network exactly (solutions, inputs, residual bits, u64 counters)
//! travels as fixed-width hex ([`protocol`]). Per-shard FNV checksums are
//! verified on receipt and cross-checked between duplicate solves, so a
//! distributed run is provably byte-identical to `skr generate --threads S`
//! on one machine, down to the summed [`crate::solver::SolveCounters`].

pub mod coordinator;
pub mod lease;
pub mod protocol;
pub mod worker;

pub use coordinator::{coordinate, coordinate_bound, CoordinateConfig, DistSummary};
pub use lease::{Disposition, Grant, LeaseConfig, LeaseTable};
pub use worker::{work, WorkerConfig};
