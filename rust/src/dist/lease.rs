//! The coordinator's lease table: a single-threaded, clock-injected state
//! machine over the run's shards. Every method takes `now_ms` so the whole
//! grant → heartbeat → expiry → re-grant → duplicate-rejection lifecycle is
//! testable without sockets or sleeps.
//!
//! Shard lifecycle: `Queued` —grant→ `Leased` —result→ `Done`. A lease that
//! misses its heartbeat deadline expires back to `Queued` with exponential
//! backoff; a shard that burns through `max_attempts` grants keeps being
//! retried (the run should still finish if a worker eventually shows up)
//! but flags the run **degraded** so operators know retries exceeded the
//! budget. `Done` is terminal: a late or repeated result for a finished
//! shard is rejected, and its checksum is compared against the accepted one
//! — a mismatch between two solves of the same shard means nondeterminism
//! or corruption, the one thing a bit-identical pipeline must never shrug
//! off.

/// Retry/timeout policy for one run.
#[derive(Debug, Clone, Copy)]
pub struct LeaseConfig {
    /// Lease lifetime without a heartbeat renewal.
    pub lease_ms: u64,
    /// Grants per shard before the run is flagged degraded.
    pub max_attempts: u32,
    /// Base requeue delay after an expiry; doubles per prior attempt,
    /// capped at `lease_ms`.
    pub backoff_ms: u64,
}

impl Default for LeaseConfig {
    fn default() -> Self {
        LeaseConfig { lease_ms: 30_000, max_attempts: 3, backoff_ms: 500 }
    }
}

#[derive(Debug, Clone)]
enum Phase {
    Queued { not_before_ms: u64 },
    Leased { worker: String, attempt: u32, deadline_ms: u64 },
    Done { checksum: u64 },
}

#[derive(Debug, Clone)]
struct Slot {
    ids: Vec<usize>,
    phase: Phase,
    /// Total grants handed out for this shard.
    attempts: u32,
}

/// Answer to a lease request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Grant {
    /// Work on these systems; renew before `deadline_ms`.
    Lease { shard: usize, attempt: u32, ids: Vec<usize>, deadline_ms: u64 },
    /// Nothing grantable right now (all leased or backing off) — poll again.
    Wait { retry_ms: u64 },
    /// Every shard is done; the worker can exit.
    Finished,
}

/// Verdict on a submitted shard result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Disposition {
    /// First valid result for the shard under a live lease — merge it.
    Accepted,
    /// The lease this result belongs to expired or was re-granted; the
    /// payload is discarded (merging it would double-fill the dataset).
    Stale,
    /// The shard already completed; carries the accepted checksum so the
    /// caller can cross-verify that the two solves agreed bit-for-bit.
    Duplicate { accepted_checksum: u64 },
    /// No such shard in the plan.
    UnknownShard,
}

/// Lease bookkeeping for one distributed run.
#[derive(Debug)]
pub struct LeaseTable {
    slots: Vec<Slot>,
    cfg: LeaseConfig,
    /// Leases handed out (`skr_dist_leases_granted_total`).
    pub granted: u64,
    /// Leases that missed their deadline (`skr_dist_leases_expired_total`).
    pub expired: u64,
    /// Requeues caused by expiry or checksum failure
    /// (`skr_dist_leases_retried_total`).
    pub retried: u64,
    /// Results rejected as duplicate or stale.
    pub duplicates: u64,
    /// Some shard exceeded the attempt budget.
    pub degraded: bool,
}

impl LeaseTable {
    pub fn new(shards: Vec<Vec<usize>>, cfg: LeaseConfig) -> LeaseTable {
        LeaseTable {
            slots: shards
                .into_iter()
                .map(|ids| Slot { ids, phase: Phase::Queued { not_before_ms: 0 }, attempts: 0 })
                .collect(),
            cfg,
            granted: 0,
            expired: 0,
            retried: 0,
            duplicates: 0,
            degraded: false,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.slots.len()
    }

    pub fn done_count(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s.phase, Phase::Done { .. })).count()
    }

    pub fn all_done(&self) -> bool {
        self.done_count() == self.slots.len()
    }

    /// The planned ids of one shard (used to validate result payloads).
    pub fn shard_ids(&self, shard: usize) -> Option<&[usize]> {
        self.slots.get(shard).map(|s| s.ids.as_slice())
    }

    /// Sweep expired leases back to the queue (with backoff). Called from
    /// every public entry point, so callers never observe a lapsed lease.
    fn expire(&mut self, now_ms: u64) {
        for slot in &mut self.slots {
            let deadline = match &slot.phase {
                Phase::Leased { deadline_ms, .. } => *deadline_ms,
                _ => continue,
            };
            if now_ms < deadline {
                continue;
            }
            self.expired += 1;
            self.retried += 1;
            if slot.attempts >= self.cfg.max_attempts {
                self.degraded = true;
            }
            // Exponential backoff on the attempts already burned, capped so
            // a flapping worker can't park a shard forever.
            let shift = slot.attempts.saturating_sub(1).min(16);
            let backoff = (self.cfg.backoff_ms << shift).min(self.cfg.lease_ms);
            slot.phase = Phase::Queued { not_before_ms: now_ms + backoff };
        }
    }

    /// Hand `worker` the lowest-numbered grantable shard, or say why not.
    pub fn grant(&mut self, worker: &str, now_ms: u64) -> Grant {
        self.expire(now_ms);
        if self.all_done() {
            return Grant::Finished;
        }
        let mut pick = None;
        for (i, slot) in self.slots.iter().enumerate() {
            if let Phase::Queued { not_before_ms } = slot.phase {
                if now_ms >= not_before_ms {
                    pick = Some(i);
                    break;
                }
            }
        }
        if let Some(i) = pick {
            let slot = &mut self.slots[i];
            slot.attempts += 1;
            self.granted += 1;
            let deadline_ms = now_ms + self.cfg.lease_ms;
            slot.phase = Phase::Leased {
                worker: worker.to_string(),
                attempt: slot.attempts,
                deadline_ms,
            };
            return Grant::Lease {
                shard: i,
                attempt: slot.attempts,
                ids: slot.ids.clone(),
                deadline_ms,
            };
        }
        // Nothing grantable: tell the worker when the earliest backoff or
        // lease deadline lands, clamped to a sane polling interval.
        let next = self
            .slots
            .iter()
            .filter_map(|s| match &s.phase {
                Phase::Queued { not_before_ms } => Some(*not_before_ms),
                Phase::Leased { deadline_ms, .. } => Some(*deadline_ms),
                Phase::Done { .. } => None,
            })
            .min()
            .unwrap_or(now_ms);
        Grant::Wait { retry_ms: next.saturating_sub(now_ms).clamp(50, 2_000) }
    }

    /// Renew a live lease. Returns `false` (worker should abandon the
    /// shard) if the lease already expired, was re-granted, or finished.
    pub fn heartbeat(&mut self, shard: usize, attempt: u32, worker: &str, now_ms: u64) -> bool {
        self.expire(now_ms);
        let lease_ms = self.cfg.lease_ms;
        let Some(slot) = self.slots.get_mut(shard) else { return false };
        match &mut slot.phase {
            Phase::Leased { worker: w, attempt: a, deadline_ms }
                if *a == attempt && w.as_str() == worker =>
            {
                *deadline_ms = now_ms + lease_ms;
                true
            }
            _ => false,
        }
    }

    /// Judge a submitted result. `Accepted` transitions the shard to
    /// `Done { checksum }`; everything else leaves the table unchanged
    /// apart from the duplicate tally.
    pub fn complete(
        &mut self,
        shard: usize,
        attempt: u32,
        worker: &str,
        checksum: u64,
        now_ms: u64,
    ) -> Disposition {
        self.expire(now_ms);
        let Some(slot) = self.slots.get_mut(shard) else { return Disposition::UnknownShard };
        let rejected = match &slot.phase {
            Phase::Done { checksum: accepted } => {
                Some(Disposition::Duplicate { accepted_checksum: *accepted })
            }
            Phase::Leased { worker: w, attempt: a, .. }
                if *a == attempt && w.as_str() == worker =>
            {
                None
            }
            // Expired-then-resubmitted, or a racing older attempt while a
            // newer lease is live: either way, not mergeable.
            _ => Some(Disposition::Stale),
        };
        match rejected {
            Some(d) => {
                self.duplicates += 1;
                d
            }
            None => {
                slot.phase = Phase::Done { checksum };
                Disposition::Accepted
            }
        }
    }

    /// Requeue a shard whose accepted-path validation failed downstream
    /// (e.g. payload checksum mismatch) so another lease can retry it.
    pub fn requeue(&mut self, shard: usize, now_ms: u64) {
        let max_attempts = self.cfg.max_attempts;
        let backoff_ms = self.cfg.backoff_ms;
        if let Some(slot) = self.slots.get_mut(shard) {
            if !matches!(slot.phase, Phase::Done { .. }) {
                self.retried += 1;
                if slot.attempts >= max_attempts {
                    self.degraded = true;
                }
                slot.phase = Phase::Queued { not_before_ms: now_ms + backoff_ms };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, Config};

    fn table(lease_ms: u64) -> LeaseTable {
        LeaseTable::new(
            vec![vec![0, 1], vec![2, 3], vec![4]],
            LeaseConfig { lease_ms, max_attempts: 2, backoff_ms: 100 },
        )
    }

    fn lease_of(g: Grant) -> (usize, u32, Vec<usize>) {
        match g {
            Grant::Lease { shard, attempt, ids, .. } => (shard, attempt, ids),
            other => panic!("expected a lease, got {other:?}"),
        }
    }

    /// Poll `grant` until a lease lands, advancing the injected clock
    /// through `Wait` answers exactly like a live worker would.
    fn next_lease(t: &mut LeaseTable, now: &mut u64, w: &str) -> (usize, u32, Vec<usize>) {
        loop {
            match t.grant(w, *now) {
                Grant::Lease { shard, attempt, ids, .. } => return (shard, attempt, ids),
                Grant::Wait { retry_ms } => *now += retry_ms.max(1),
                Grant::Finished => panic!("finished before a lease was granted"),
            }
        }
    }

    #[test]
    fn lifecycle_grant_heartbeat_expire_regrant_duplicate() {
        let mut t = table(1_000);
        // Grant: lowest queued shard first.
        let (shard, attempt, ids) = lease_of(t.grant("w1", 0));
        assert_eq!((shard, attempt), (0, 1));
        assert_eq!(ids, vec![0, 1]);
        // Heartbeat renews past the original deadline.
        assert!(t.heartbeat(0, 1, "w1", 900));
        let g = t.grant("w2", 1_500); // w1's lease is renewed until 1_900
        assert_eq!(lease_of(g).0, 1, "renewed shard 0 must not be re-granted");
        // No heartbeats → both leases lapse. The grant at 2_500 detects the
        // expiries (requeue with backoff) and hands out untouched shard 2.
        let (s, a, _) = lease_of(t.grant("w2", 2_500));
        assert_eq!((s, a), (2, 1));
        assert_eq!(t.expired, 2, "both w1's shard-0 and w2's shard-1 leases lapsed");
        // Once the backoff passes, shard 0 is re-granted with a bumped attempt.
        let mut now = 2_700;
        let (s, a, ids) = next_lease(&mut t, &mut now, "w3");
        assert_eq!((s, a), (0, 2));
        assert_eq!(ids, vec![0, 1], "re-granted shard carries the same ids");
        // The expired holder's result is stale, not mergeable.
        assert_eq!(t.complete(0, 1, "w1", 0xAAAA, now + 10), Disposition::Stale);
        // The live lease completes.
        assert_eq!(t.complete(0, 2, "w3", 0xBEEF, now + 20), Disposition::Accepted);
        // A duplicate is rejected and reports the accepted checksum.
        assert_eq!(
            t.complete(0, 2, "w3", 0xBEEF, now + 30),
            Disposition::Duplicate { accepted_checksum: 0xBEEF }
        );
        assert!(!t.all_done());
        assert_eq!(t.done_count(), 1);
        assert_eq!(t.duplicates, 2);
        assert_eq!(t.complete(99, 1, "w3", 0, now + 40), Disposition::UnknownShard);
    }

    #[test]
    fn heartbeat_of_lapsed_or_regranted_lease_fails() {
        let mut t = table(1_000);
        let (shard, attempt, _) = lease_of(t.grant("w1", 0));
        // At the deadline the heartbeat itself observes the expiry.
        assert!(!t.heartbeat(shard, attempt, "w1", 1_000));
        // Inside the backoff window shard 0 is not grantable; shard 1 is.
        assert!(matches!(t.grant("w1", 1_050), Grant::Lease { shard: 1, .. }));
        let (s2, a2, _) = lease_of(t.grant("w2", 1_200));
        assert_eq!((s2, a2), (0, 2));
        // The old holder can't renew the re-granted lease either.
        assert!(!t.heartbeat(0, 1, "w1", 1_300));
        assert!(t.heartbeat(0, 2, "w2", 1_300));
    }

    #[test]
    fn exceeding_attempt_budget_flags_degraded_but_run_can_finish() {
        let mut t = LeaseTable::new(
            vec![vec![0]],
            LeaseConfig { lease_ms: 100, max_attempts: 2, backoff_ms: 10 },
        );
        let mut now = 0;
        for expected_attempt in 1..=3u32 {
            let (_, attempt, _) = next_lease(&mut t, &mut now, "w");
            assert_eq!(attempt, expected_attempt);
            now += 10_000; // let the lease lapse
        }
        assert!(t.degraded, "a third grant means the 2-attempt budget was blown");
        let (_, attempt, _) = next_lease(&mut t, &mut now, "w");
        assert_eq!(t.complete(0, attempt, "w", 7, now), Disposition::Accepted);
        assert!(t.all_done(), "degraded runs still complete");
        assert!(matches!(t.grant("w", now + 1), Grant::Finished));
    }

    #[test]
    fn wait_tells_the_worker_when_to_come_back() {
        let mut t = table(1_000);
        let _ = t.grant("w1", 0);
        let _ = t.grant("w1", 0);
        let _ = t.grant("w1", 0);
        match t.grant("w2", 0) {
            Grant::Wait { retry_ms } => assert!((50..=2_000).contains(&retry_ms), "{retry_ms}"),
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    fn requeue_after_downstream_rejection_allows_retry() {
        let mut t = table(1_000);
        let (shard, attempt, _) = lease_of(t.grant("w1", 0));
        assert_eq!(t.complete(shard, attempt, "w1", 1, 10), Disposition::Accepted);
        // Done shards are immune to requeue.
        t.requeue(shard, 20);
        assert_eq!(t.done_count(), 1);
        // A live lease can be requeued (the checksum-mismatch path).
        let (s2, _, _) = lease_of(t.grant("w1", 30));
        t.requeue(s2, 40);
        let mut now = 150;
        let (s3, a3, _) = next_lease(&mut t, &mut now, "w2");
        assert_eq!(s3, s2);
        assert_eq!(a3, 2);
    }

    /// Propcheck: drive random op sequences and assert the machine never
    /// violates its core invariants — socket-free, clock-injected.
    #[test]
    fn random_op_sequences_preserve_invariants() {
        propcheck::check_msg(
            "lease_table_invariants",
            Config { cases: 128, seed: 0xD157 },
            |rng| {
                let shards = 1 + rng.below(4);
                let ops: Vec<(u8, usize, usize)> = (0..60)
                    .map(|_| (rng.below(4) as u8, rng.below(shards), rng.below(3)))
                    .collect();
                (shards, ops)
            },
            |(shards, ops)| {
                let mut t = LeaseTable::new(
                    (0..*shards).map(|s| vec![s]).collect(),
                    LeaseConfig { lease_ms: 50, max_attempts: 2, backoff_ms: 5 },
                );
                let mut now = 0u64;
                let workers = ["wa", "wb", "wc"];
                // Leases we believe are live: (shard, attempt, worker index).
                let mut live: Vec<(usize, u32, usize)> = Vec::new();
                let mut accepted = std::collections::BTreeMap::<usize, u64>::new();
                for &(op, target, widx) in ops {
                    now += 13; // time always advances
                    match op {
                        0 => {
                            if let Grant::Lease { shard, attempt, ids, .. } =
                                t.grant(workers[widx], now)
                            {
                                if accepted.contains_key(&shard) {
                                    return Err(format!("re-granted done shard {shard}"));
                                }
                                if ids != [shard] {
                                    return Err(format!("shard {shard} ids changed: {ids:?}"));
                                }
                                live.retain(|(s, _, _)| *s != shard);
                                live.push((shard, attempt, widx));
                            }
                        }
                        1 => {
                            if let Some(&(s, a, lw)) = live.iter().find(|(s, _, _)| *s == target) {
                                let _ = t.heartbeat(s, a, workers[lw], now);
                            }
                        }
                        2 => {
                            if let Some(pos) = live.iter().position(|(s, _, _)| *s == target) {
                                let (s, a, lw) = live.remove(pos);
                                let sum = ((s as u64) << 8) | 1;
                                match t.complete(s, a, workers[lw], sum, now) {
                                    Disposition::Accepted => {
                                        if accepted.insert(s, sum).is_some() {
                                            return Err(format!("shard {s} accepted twice"));
                                        }
                                    }
                                    Disposition::Duplicate { accepted_checksum } => {
                                        if accepted.get(&s) != Some(&accepted_checksum) {
                                            return Err(format!(
                                                "duplicate for {s} reported wrong checksum"
                                            ));
                                        }
                                    }
                                    Disposition::Stale => {}
                                    Disposition::UnknownShard => {
                                        return Err(format!("known shard {s} reported unknown"));
                                    }
                                }
                            }
                        }
                        _ => now += 200, // long stall: leases lapse
                    }
                    if t.done_count() != accepted.len() {
                        return Err(format!(
                            "done_count {} diverged from accepted {}",
                            t.done_count(),
                            accepted.len()
                        ));
                    }
                }
                // Drain: keep granting + completing until finished.
                let mut guard = 0;
                while !t.all_done() {
                    now += 29;
                    match t.grant("drain", now) {
                        Grant::Lease { shard, attempt, .. } => {
                            let sum = ((shard as u64) << 8) | 1;
                            if t.complete(shard, attempt, "drain", sum, now)
                                == Disposition::Accepted
                            {
                                accepted.insert(shard, sum);
                            }
                        }
                        Grant::Wait { retry_ms } => now += retry_ms,
                        Grant::Finished => break,
                    }
                    guard += 1;
                    if guard > 10_000 {
                        return Err("drain did not converge".into());
                    }
                }
                if !t.all_done() || accepted.len() != *shards {
                    return Err(format!("run never finished: {}/{shards} done", accepted.len()));
                }
                if t.granted < *shards as u64 {
                    return Err("fewer grants than shards".into());
                }
                Ok(())
            },
        );
    }
}
