//! The dist wire format: JSON envelopes over `service::http`, with every
//! payload that must survive the network **bit-exactly** (solutions, input
//! fields, residuals, 64-bit op counters and checksums) carried as
//! fixed-width lowercase hex rather than JSON numbers.
//!
//! Why hex: the crate's JSON emitter prints integral `f64`s through an
//! integer fast path, which erases the sign of `-0.0`, and a `u64` counter
//! above 2⁵³ cannot round-trip an `f64` at all. Sixteen hex chars per value
//! encode the exact little-endian bytes, so a distributed run can be
//! byte-compared against a single-node one.
//!
//! | Method & path              | Body → response                           |
//! |----------------------------|-------------------------------------------|
//! | `GET /plan`                | run spec + shard layout + protocol version|
//! | `POST /lease`              | `{worker}` → lease / wait / finished      |
//! | `POST /heartbeat`          | `{shard, attempt, worker}` → `{ok}`       |
//! | `POST /shards/:id/result`  | [`ShardResultMsg`] → `{disposition}`      |
//! | `GET /metrics`             | Prometheus text (`skr_dist_*` + run)      |

use crate::solver::{SolveCounters, SolveStats, StopReason};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Bumped on every incompatible wire change; `/plan` advertises it and
/// workers refuse to join a coordinator speaking another version.
pub const PROTOCOL_VERSION: usize = 1;

/// Body cap for `POST /shards/:id/result` — a shard of solutions dwarfs the
/// service API's 4 MB default.
pub const MAX_RESULT_BODY: usize = 256 * 1024 * 1024;

/// Encode a `u64` as 16 lowercase hex chars (big-endian digit order).
pub fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

/// Inverse of [`hex_u64`].
pub fn parse_hex_u64(s: &str) -> Result<u64> {
    if s.len() != 16 {
        bail!("expected 16 hex chars, got {} in {s:?}", s.len());
    }
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 {s:?}"))
}

/// Encode a slice of `f64`s as one hex string: 16 chars per value, each the
/// little-endian byte image. Exact for every value including `-0.0`, NaN
/// payloads and subnormals.
pub fn encode_f64s(xs: &[f64]) -> String {
    let mut out = String::with_capacity(xs.len() * 16);
    for x in xs {
        for b in x.to_le_bytes() {
            out.push(char::from_digit((b >> 4) as u32, 16).unwrap());
            out.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
        }
    }
    out
}

/// Inverse of [`encode_f64s`].
pub fn decode_f64s(s: &str) -> Result<Vec<f64>> {
    let bytes = s.as_bytes();
    if bytes.len() % 16 != 0 {
        bail!("hex f64 payload length {} is not a multiple of 16", bytes.len());
    }
    let nibble = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            other => bail!("bad hex digit {:?}", other as char),
        }
    };
    let mut out = Vec::with_capacity(bytes.len() / 16);
    for chunk in bytes.chunks_exact(16) {
        let mut le = [0u8; 8];
        for (i, pair) in chunk.chunks_exact(2).enumerate() {
            le[i] = (nibble(pair[0])? << 4) | nibble(pair[1])?;
        }
        out.push(f64::from_le_bytes(le));
    }
    Ok(out)
}

/// Streaming FNV-1a (64-bit) — the shard integrity checksum. Deliberately
/// simple and dependency-free; it guards against transport corruption and
/// nondeterministic re-solves, not adversaries.
#[derive(Debug, Clone, Copy)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64(0xcbf29ce484222325)
    }
}

impl Fnv64 {
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Checksum one solved shard: for each system in shard order, the original
/// id (little-endian `u64`) then the exact input and solution bytes. Both
/// sides compute this over their own copy, so a flipped bit anywhere in the
/// payload — or a re-solve that didn't reproduce the same bits — is caught.
pub fn shard_checksum(systems: &[SystemResult]) -> u64 {
    let mut h = Fnv64::default();
    for sys in systems {
        h.update(&(sys.id as u64).to_le_bytes());
        for x in &sys.input {
            h.update(&x.to_le_bytes());
        }
        for x in &sys.solution {
            h.update(&x.to_le_bytes());
        }
    }
    h.finish()
}

pub fn counters_to_json(c: &SolveCounters) -> Json {
    Json::obj(c.fields().iter().map(|&(name, v)| (name, Json::Str(hex_u64(v)))).collect())
}

pub fn counters_from_json(j: &Json) -> Result<SolveCounters> {
    let field = |key: &str| -> Result<u64> {
        parse_hex_u64(
            j.get(key)
                .and_then(|v| v.as_str())
                .with_context(|| format!("counters missing {key:?}"))?,
        )
    };
    Ok(SolveCounters {
        matvecs: field("matvecs")?,
        precond_applies: field("precond_applies")?,
        ortho_flops: field("ortho_flops")?,
        recycle_reseeds: field("recycle_reseeds")?,
        recycle_carries: field("recycle_carries")?,
        harvests: field("harvests")?,
    })
}

/// One solved system on the wire.
#[derive(Debug, Clone)]
pub struct SystemResult {
    /// Original stream id (the dataset row).
    pub id: usize,
    /// The family's input field for the sample.
    pub input: Vec<f64>,
    pub solution: Vec<f64>,
    pub stats: SolveStats,
}

impl SystemResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("iters", Json::Num(self.stats.iters as f64)),
            ("seconds", Json::Num(self.stats.seconds)),
            // Bit-exact: the residual feeds the merged metrics verbatim.
            ("rel_residual", Json::Str(hex_u64(self.stats.rel_residual.to_bits()))),
            ("stop", Json::Str(self.stats.stop.label().to_string())),
            ("input", Json::Str(encode_f64s(&self.input))),
            ("solution", Json::Str(encode_f64s(&self.solution))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SystemResult> {
        let str_field = |key: &str| -> Result<&str> {
            j.get(key).and_then(|v| v.as_str()).with_context(|| format!("missing {key:?}"))
        };
        let num_field = |key: &str| -> Result<f64> {
            j.get(key).and_then(|v| v.as_f64()).with_context(|| format!("missing {key:?}"))
        };
        Ok(SystemResult {
            id: num_field("id")? as usize,
            input: decode_f64s(str_field("input")?)?,
            solution: decode_f64s(str_field("solution")?)?,
            stats: SolveStats {
                iters: num_field("iters")? as usize,
                seconds: num_field("seconds")?,
                rel_residual: f64::from_bits(parse_hex_u64(str_field("rel_residual")?)?),
                stop: StopReason::parse(str_field("stop")?)?,
                trace: vec![],
            },
        })
    }
}

/// `POST /shards/:id/result` body: everything the coordinator needs to
/// merge one shard and fold its tallies into the run metrics.
#[derive(Debug, Clone)]
pub struct ShardResultMsg {
    pub shard: usize,
    /// Which grant of this shard produced the result (lease retries bump it).
    pub attempt: u32,
    pub worker: String,
    pub systems: Vec<SystemResult>,
    pub counters: SolveCounters,
    pub sparsity_reuse: usize,
    pub symbolic_reuse: usize,
    pub workspace_reuse: usize,
    /// FNV-1a over ids + payload bytes — see [`shard_checksum`].
    pub checksum: u64,
}

impl ShardResultMsg {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("attempt", Json::Num(self.attempt as f64)),
            ("worker", Json::Str(self.worker.clone())),
            ("checksum", Json::Str(hex_u64(self.checksum))),
            ("counters", counters_to_json(&self.counters)),
            ("sparsity_reuse", Json::Num(self.sparsity_reuse as f64)),
            ("symbolic_reuse", Json::Num(self.symbolic_reuse as f64)),
            ("workspace_reuse", Json::Num(self.workspace_reuse as f64)),
            ("systems", Json::Arr(self.systems.iter().map(|s| s.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardResultMsg> {
        let num_field = |key: &str| -> Result<f64> {
            j.get(key).and_then(|v| v.as_f64()).with_context(|| format!("missing {key:?}"))
        };
        let systems = j
            .get("systems")
            .and_then(|v| v.as_arr())
            .context("missing \"systems\"")?
            .iter()
            .map(SystemResult::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardResultMsg {
            shard: num_field("shard")? as usize,
            attempt: num_field("attempt")? as u32,
            worker: j
                .get("worker")
                .and_then(|v| v.as_str())
                .context("missing \"worker\"")?
                .to_string(),
            systems,
            counters: counters_from_json(j.get("counters").context("missing \"counters\"")?)?,
            sparsity_reuse: num_field("sparsity_reuse")? as usize,
            symbolic_reuse: num_field("symbolic_reuse")? as usize,
            workspace_reuse: num_field("workspace_reuse")? as usize,
            checksum: parse_hex_u64(
                j.get("checksum").and_then(|v| v.as_str()).context("missing \"checksum\"")?,
            )?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_hex_codec_is_bit_exact() {
        let xs = vec![
            0.0,
            -0.0,
            1.0,
            -1.5,
            f64::MIN_POSITIVE,
            f64::MIN_POSITIVE / 2.0, // subnormal
            f64::MAX,
            f64::NEG_INFINITY,
            f64::NAN,
            std::f64::consts::PI,
        ];
        let back = decode_f64s(&encode_f64s(&xs)).unwrap();
        assert_eq!(back.len(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} lost bits over the wire");
        }
        // -0.0 specifically: the JSON number path would print it as 0.
        assert_eq!(back[1].to_bits(), (-0.0f64).to_bits());
        assert!(decode_f64s("abc").is_err());
        assert!(decode_f64s("zz00000000000000").is_err());
    }

    #[test]
    fn hex_u64_round_trips() {
        for v in [0u64, 1, u64::MAX, 0xcbf29ce484222325, (1u64 << 53) + 1] {
            assert_eq!(parse_hex_u64(&hex_u64(v)).unwrap(), v);
        }
        assert!(parse_hex_u64("123").is_err());
    }

    fn sample_result(id: usize) -> SystemResult {
        SystemResult {
            id,
            input: vec![0.5, -0.0, 3.25],
            solution: vec![1.0, 2.0, -4.5],
            stats: SolveStats {
                iters: 17,
                seconds: 0.125,
                rel_residual: 3.2e-9,
                stop: StopReason::Converged,
                trace: vec![],
            },
        }
    }

    #[test]
    fn shard_result_round_trips_and_checksums() {
        let systems = vec![sample_result(4), sample_result(9)];
        let msg = ShardResultMsg {
            shard: 2,
            attempt: 3,
            worker: "w1".into(),
            checksum: shard_checksum(&systems),
            counters: SolveCounters {
                matvecs: 10,
                precond_applies: 9,
                ortho_flops: (1 << 60) + 7, // above 2^53: JSON numbers would round
                recycle_reseeds: 1,
                recycle_carries: 2,
                harvests: 3,
            },
            sparsity_reuse: 1,
            symbolic_reuse: 1,
            workspace_reuse: 1,
            systems,
        };
        let back =
            ShardResultMsg::from_json(&Json::parse(&msg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.shard, 2);
        assert_eq!(back.attempt, 3);
        assert_eq!(back.counters, msg.counters);
        assert_eq!(back.checksum, msg.checksum);
        assert_eq!(shard_checksum(&back.systems), msg.checksum);
        for (a, b) in msg.systems.iter().zip(&back.systems) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.stats.iters, b.stats.iters);
            assert_eq!(a.stats.stop, b.stats.stop);
            assert_eq!(a.stats.rel_residual.to_bits(), b.stats.rel_residual.to_bits());
            assert_eq!(a.input, b.input);
            assert_eq!(a.solution, b.solution);
        }
    }

    #[test]
    fn checksum_is_order_and_content_sensitive() {
        let a = vec![sample_result(1), sample_result(2)];
        let mut swapped = vec![sample_result(2), sample_result(1)];
        assert_ne!(shard_checksum(&a), shard_checksum(&swapped));
        swapped.reverse();
        assert_eq!(shard_checksum(&a), shard_checksum(&swapped));
        let mut tweaked = a.clone();
        tweaked[0].solution[0] = f64::from_bits(tweaked[0].solution[0].to_bits() ^ 1);
        assert_ne!(shard_checksum(&a), shard_checksum(&tweaked));
    }
}
