//! `skr coordinate` — the lease-granting, result-merging side of a
//! distributed run.
//!
//! The coordinator computes the *same* deterministic plan as a single-node
//! `skr generate` ([`Pipeline::plan`]: parameter pass → similarity sort →
//! contiguous shards), then serves the shards to workers over the
//! `service::http` framing. Results stream back per shard; each is
//! validated (planned ids, dimensions, FNV checksum) before it is merged
//! id-indexed into the [`DatasetWriter`] — so the finished dataset is
//! byte-identical to the single-node run with `--threads` equal to the
//! shard count, and the summed [`SolveCounters`] match exactly.
//!
//! The accept loop is single-threaded and nonblocking: leases, heartbeats
//! and merges all mutate one [`LeaseTable`] without locks, and expiry is
//! swept on every request. After the last shard lands the coordinator
//! finalizes the dataset, then lingers briefly answering `finished` so
//! slow workers exit cleanly instead of erroring on a dead socket.

use super::lease::{Disposition, Grant, LeaseConfig, LeaseTable};
use super::protocol::{shard_checksum, ShardResultMsg, MAX_RESULT_BODY, PROTOCOL_VERSION};
use crate::coordinator::dataset::{DatasetSummary, DatasetWriter};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::Pipeline;
use crate::obs::{Recorder, SpanRecord};
use crate::service::http::{read_request_capped, write_response, Request, Response};
use crate::service::JobSpec;
use crate::solver::{SolveCounters, SolveStats};
use crate::util::args::Args;
use crate::util::json::Json;
use crate::util::timer::Timer;
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Configuration for one coordinated run.
#[derive(Debug, Clone)]
pub struct CoordinateConfig {
    /// Listen address, e.g. `127.0.0.1:7171` (port 0 = ephemeral).
    pub bind: String,
    /// The generation job — same fields and defaults as `skr generate`.
    pub spec: JobSpec,
    /// Shard count; the distributed run is bit-identical to a single-node
    /// `skr generate --threads <shards>`.
    pub shards: usize,
    pub lease: LeaseConfig,
    /// How long to keep answering `finished` after the run completes.
    pub linger_ms: u64,
}

impl CoordinateConfig {
    pub fn from_args(args: &Args) -> CoordinateConfig {
        let spec = JobSpec::from_args(args);
        let shards = args.num_or("shards", spec.threads).max(1);
        CoordinateConfig {
            bind: format!(
                "{}:{}",
                args.str_or("host", "127.0.0.1"),
                args.num_or("port", 7171u16)
            ),
            spec,
            shards,
            lease: LeaseConfig {
                lease_ms: args.num_or("lease-ms", 30_000u64),
                max_attempts: args.num_or("max-attempts", 3u32),
                backoff_ms: args.num_or("backoff-ms", 500u64),
            },
            linger_ms: args.num_or("linger-ms", 1_000u64),
        }
    }
}

/// What a coordinated run produced.
#[derive(Debug)]
pub struct DistSummary {
    pub systems: usize,
    pub shards: usize,
    pub granted: u64,
    pub expired: u64,
    pub retried: u64,
    pub duplicates: u64,
    pub degraded: bool,
    /// Total accepted result-payload bytes.
    pub bytes_merged: u64,
    pub dataset: Option<DatasetSummary>,
    /// Folded in shard order — identical to the single-node aggregation.
    pub metrics: RunMetrics,
    /// `gen`/`sort`/`shard` plan spans plus one `dist/shard{i}` span per
    /// accepted shard (grant → merge).
    pub spans: Vec<SpanRecord>,
}

/// Bind `cfg.bind` and run the coordinator to completion.
pub fn coordinate(cfg: &CoordinateConfig) -> Result<DistSummary> {
    let listener = TcpListener::bind(&cfg.bind)
        .with_context(|| format!("binding coordinator to {}", cfg.bind))?;
    coordinate_bound(cfg, listener)
}

/// [`coordinate`] on a caller-bound listener (tests bind an ephemeral port
/// first so they know the address before the coordinator starts).
pub fn coordinate_bound(cfg: &CoordinateConfig, listener: TcpListener) -> Result<DistSummary> {
    let wall = Timer::start();
    let mut spec = cfg.spec.clone();
    if spec.out.is_none() {
        spec.out = Some(format!(
            "results/dist_{}_{}",
            spec.family.to_lowercase(),
            spec.count
        ));
    }
    let pcfg = spec.to_config()?;
    let pipe = Pipeline::new(pcfg);
    let nshards = cfg.shards.max(1);
    let recorder = Recorder::new();
    let plan = pipe.plan_recorded(nshards, &recorder)?;
    let count = pipe.config().count;
    let input_dim = plan.params.first().map_or(0, |p| p.len());
    let sol_dim = pipe.family().num_unknowns();
    let out_dir = pipe.config().out_dir.clone().context("no output directory")?;

    let plan_body = Json::obj(vec![
        ("version", Json::Num(PROTOCOL_VERSION as f64)),
        ("spec", spec.to_json()),
        ("count", Json::Num(count as f64)),
        (
            "shards",
            Json::Arr(
                plan.shards
                    .iter()
                    .map(|ids| Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect()))
                    .collect(),
            ),
        ),
    ])
    .dump();

    let mut coord = Coord {
        lease_cfg: cfg.lease,
        table: LeaseTable::new(plan.shards.clone(), cfg.lease),
        writer: Some(DatasetWriter::new(
            &out_dir,
            count,
            input_dim,
            sol_dim,
            pipe.family().field_side(),
        )),
        done: (0..nshards).map(|_| None).collect(),
        grant_started: vec![0.0; nshards],
        recorder,
        gen_seconds: plan.gen_seconds,
        sort_seconds: plan.sort_seconds,
        bytes_merged: 0,
        plan_body,
        input_dim,
        sol_dim,
    };

    listener.set_nonblocking(true).context("nonblocking accept")?;
    let local = listener.local_addr()?;
    println!("coordinator listening on {local} ({count} systems in {nshards} shards)");

    let epoch = Instant::now();
    let mut finished_at: Option<u64> = None;
    let mut dataset: Option<DatasetSummary> = None;
    let mut metrics = RunMetrics::default();
    loop {
        match listener.accept() {
            Ok((mut stream, _)) => {
                if let Err(e) = serve_one(&mut coord, &mut stream, &epoch) {
                    eprintln!("dist: connection error: {e:#}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accepting worker connection"),
        }
        if !coord.table.all_done() {
            continue;
        }
        let now_ms = epoch.elapsed().as_millis() as u64;
        if coord.writer.is_some() {
            // All shards merged: finalize exactly as `skr generate` does
            // (same meta extras, same staged atomic rename).
            let writer = coord.writer.take().unwrap();
            metrics = coord.fold_metrics();
            metrics.wall_seconds = wall.secs();
            let ds = writer
                .finalize(
                    pipe.family().name(),
                    vec![
                        ("engine", Json::Str(pipe.config().engine.label().into())),
                        ("tol", Json::Num(pipe.config().solver.tol)),
                        ("seed", Json::Num(pipe.config().seed as f64)),
                    ],
                )
                .context("finalizing dataset")?;
            let t = &coord.table;
            println!(
                "dist: {} systems in {nshards} shards; leases: granted {} expired {} \
                 retried {} duplicates {}{}",
                metrics.systems,
                t.granted,
                t.expired,
                t.retried,
                t.duplicates,
                if t.degraded { "  DEGRADED" } else { "" }
            );
            println!(
                "ops: matvecs {}  precond {}  ortho_flops {}  \
                 recycle carry/reseed/harvest {}/{}/{}",
                metrics.counters.matvecs,
                metrics.counters.precond_applies,
                metrics.counters.ortho_flops,
                metrics.counters.recycle_carries,
                metrics.counters.recycle_reseeds,
                metrics.counters.harvests
            );
            println!("dataset: {} ({} samples)", ds.dir.display(), ds.count);
            dataset = Some(ds);
        }
        // Linger so stragglers get a clean `finished` instead of a dead
        // socket, then stop accepting.
        let t = *finished_at.get_or_insert(now_ms);
        if now_ms.saturating_sub(t) >= cfg.linger_ms {
            break;
        }
    }

    Ok(DistSummary {
        systems: metrics.systems,
        shards: nshards,
        granted: coord.table.granted,
        expired: coord.table.expired,
        retried: coord.table.retried,
        duplicates: coord.table.duplicates,
        degraded: coord.table.degraded,
        bytes_merged: coord.bytes_merged,
        dataset,
        metrics,
        spans: coord.recorder.spans(),
    })
}

/// Everything an accepted shard contributes beyond the dataset rows,
/// buffered so the run metrics can be folded in shard order (matching the
/// single-node aggregation bit for bit).
struct ShardDone {
    stats: Vec<SolveStats>,
    counters: SolveCounters,
    sparsity_reuse: usize,
    symbolic_reuse: usize,
    workspace_reuse: usize,
}

struct Coord {
    lease_cfg: LeaseConfig,
    table: LeaseTable,
    writer: Option<DatasetWriter>,
    done: Vec<Option<ShardDone>>,
    /// Recorder-relative start of each shard's latest grant.
    grant_started: Vec<f64>,
    recorder: Recorder,
    gen_seconds: f64,
    sort_seconds: f64,
    bytes_merged: u64,
    plan_body: String,
    input_dim: usize,
    sol_dim: usize,
}

fn err_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

fn serve_one(coord: &mut Coord, stream: &mut TcpStream, epoch: &Instant) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let req = read_request_capped(stream, MAX_RESULT_BODY)?;
    let now_ms = epoch.elapsed().as_millis() as u64;
    let resp = coord.handle(&req, now_ms);
    write_response(stream, &resp)
}

impl Coord {
    fn handle(&mut self, req: &Request, now_ms: u64) -> Response {
        match self.route(req, now_ms) {
            Ok(resp) => resp,
            Err(e) => Response::json(500, err_body(&format!("{e:#}"))),
        }
    }

    fn route(&mut self, req: &Request, now_ms: u64) -> Result<Response> {
        let segs = req.segments();
        Ok(match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["plan"]) => Response::json(200, self.plan_body.clone()),
            ("GET", ["healthz"]) => Response::json(
                200,
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("done", Json::Bool(self.table.all_done())),
                ])
                .dump(),
            ),
            ("GET", ["metrics"]) => Response::text(200, self.metrics_text()),
            ("POST", ["lease"]) => self.lease(req, now_ms)?,
            ("POST", ["heartbeat"]) => self.heartbeat(req, now_ms)?,
            ("POST", ["shards", id, "result"]) => match id.parse::<usize>() {
                Ok(shard) => self.result(shard, req, now_ms)?,
                Err(_) => Response::json(400, err_body("shard id must be an integer")),
            },
            ("GET" | "POST" | "DELETE", _) => Response::json(404, err_body("no such endpoint")),
            _ => Response::json(405, err_body("method not allowed")),
        })
    }

    fn lease(&mut self, req: &Request, now_ms: u64) -> Result<Response> {
        let j = parse_body(req)?;
        let worker = j.get("worker").and_then(|v| v.as_str()).unwrap_or("anon").to_string();
        let body = match self.table.grant(&worker, now_ms) {
            Grant::Lease { shard, attempt, ids, deadline_ms } => {
                self.grant_started[shard] = self.recorder.now();
                println!(
                    "lease shard {shard} attempt {attempt} -> {worker} ({} systems)",
                    ids.len()
                );
                Json::obj(vec![
                    ("grant", Json::Str("lease".into())),
                    ("shard", Json::Num(shard as f64)),
                    ("attempt", Json::Num(attempt as f64)),
                    ("lease_ms", Json::Num(self.lease_cfg.lease_ms as f64)),
                    ("deadline_ms", Json::Num(deadline_ms as f64)),
                    ("ids", Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect())),
                ])
            }
            Grant::Wait { retry_ms } => Json::obj(vec![
                ("grant", Json::Str("wait".into())),
                ("retry_ms", Json::Num(retry_ms as f64)),
            ]),
            Grant::Finished => Json::obj(vec![("grant", Json::Str("finished".into()))]),
        };
        Ok(Response::json(200, body.dump()))
    }

    fn heartbeat(&mut self, req: &Request, now_ms: u64) -> Result<Response> {
        let j = parse_body(req)?;
        let num = |key: &str| -> Result<usize> {
            j.get(key).and_then(|v| v.as_usize()).with_context(|| format!("missing {key:?}"))
        };
        let worker = j.get("worker").and_then(|v| v.as_str()).unwrap_or("anon").to_string();
        let ok = self.table.heartbeat(num("shard")?, num("attempt")? as u32, &worker, now_ms);
        Ok(Response::json(200, Json::obj(vec![("ok", Json::Bool(ok))]).dump()))
    }

    fn result(&mut self, shard: usize, req: &Request, now_ms: u64) -> Result<Response> {
        let msg = ShardResultMsg::from_json(&parse_body(req)?)?;
        if msg.shard != shard {
            return Ok(Response::json(
                400,
                err_body(&format!("body says shard {} but path says {shard}", msg.shard)),
            ));
        }
        let Some(planned) = self.table.shard_ids(shard) else {
            return Ok(Response::json(404, err_body(&format!("no shard {shard}"))));
        };
        let got: Vec<usize> = msg.systems.iter().map(|s| s.id).collect();
        if got != planned {
            return Ok(Response::json(
                400,
                err_body(&format!("shard {shard} ids {got:?} do not match the plan")),
            ));
        }
        for sys in &msg.systems {
            if sys.input.len() != self.input_dim || sys.solution.len() != self.sol_dim {
                return Ok(Response::json(
                    400,
                    err_body(&format!("system {} has wrong dimensions", sys.id)),
                ));
            }
        }
        // Integrity: recompute the checksum over the received bytes. A
        // mismatch means the payload was corrupted in flight — requeue so
        // another lease can re-solve the shard. Only the live lease holder
        // may trigger the requeue (the heartbeat probe checks exactly
        // that), so a corrupt *stale* payload can't clobber a newer lease.
        if shard_checksum(&msg.systems) != msg.checksum {
            if self.table.heartbeat(shard, msg.attempt, &msg.worker, now_ms) {
                self.table.requeue(shard, now_ms);
            }
            return Ok(Response::json(
                400,
                err_body(&format!("shard {shard} checksum mismatch; requeued")),
            ));
        }
        match self.table.complete(shard, msg.attempt, &msg.worker, msg.checksum, now_ms) {
            Disposition::Accepted => {
                let writer = self.writer.as_mut().context("dataset already finalized")?;
                for sys in &msg.systems {
                    writer.put(sys.id, &sys.input, &sys.solution)?;
                }
                self.bytes_merged += req.body.len() as u64;
                let start = self.grant_started[shard];
                self.recorder.record(
                    &format!("dist/shard{shard}"),
                    Some(shard),
                    start,
                    self.recorder.now() - start,
                );
                self.done[shard] = Some(ShardDone {
                    stats: msg.systems.into_iter().map(|s| s.stats).collect(),
                    counters: msg.counters,
                    sparsity_reuse: msg.sparsity_reuse,
                    symbolic_reuse: msg.symbolic_reuse,
                    workspace_reuse: msg.workspace_reuse,
                });
                Ok(Response::json(200, disposition_body("accepted")))
            }
            Disposition::Duplicate { accepted_checksum } => {
                if accepted_checksum != msg.checksum {
                    // Two solves of the same shard disagreed bit-for-bit:
                    // the determinism contract is broken, flag the run.
                    self.table.degraded = true;
                    eprintln!(
                        "WARNING: shard {shard} re-solve produced different bits \
                         ({:016x} vs accepted {:016x})",
                        msg.checksum, accepted_checksum
                    );
                    return Ok(Response::json(
                        409,
                        err_body(&format!("shard {shard} duplicate diverged from accepted result")),
                    ));
                }
                Ok(Response::json(200, disposition_body("duplicate")))
            }
            Disposition::Stale => Ok(Response::json(200, disposition_body("stale"))),
            Disposition::UnknownShard => {
                Ok(Response::json(404, err_body(&format!("no shard {shard}"))))
            }
        }
    }

    /// Fold accepted shards **in shard order** — the same order the
    /// single-node pipeline reduces its workers — so every aggregate
    /// (including f64 sums) matches `skr generate` exactly.
    fn fold_metrics(&self) -> RunMetrics {
        let mut m = RunMetrics {
            gen_seconds: self.gen_seconds,
            sort_seconds: self.sort_seconds,
            ..Default::default()
        };
        for d in self.done.iter().flatten() {
            for s in &d.stats {
                m.absorb(s);
            }
            m.sparsity_reuse += d.sparsity_reuse;
            m.symbolic_reuse += d.symbolic_reuse;
            m.workspace_reuse += d.workspace_reuse;
            m.counters.merge(&d.counters);
        }
        m
    }

    fn metrics_text(&self) -> String {
        use std::fmt::Write as _;
        let t = &self.table;
        let mut out = String::new();
        let mut series = |name: &str, kind: &str, v: f64| {
            let _ = writeln!(out, "# TYPE {name} {kind}");
            let _ = writeln!(out, "{name} {v}");
        };
        series("skr_dist_leases_granted_total", "counter", t.granted as f64);
        series("skr_dist_leases_expired_total", "counter", t.expired as f64);
        series("skr_dist_leases_retried_total", "counter", t.retried as f64);
        series("skr_dist_duplicates_total", "counter", t.duplicates as f64);
        series("skr_dist_bytes_merged_total", "counter", self.bytes_merged as f64);
        series("skr_dist_shards_total", "gauge", t.shard_count() as f64);
        series("skr_dist_shards_done", "gauge", t.done_count() as f64);
        series("skr_dist_degraded", "gauge", if t.degraded { 1.0 } else { 0.0 });
        out.push_str(&self.fold_metrics().prometheus_text());
        out
    }
}

fn disposition_body(d: &str) -> String {
    Json::obj(vec![("disposition", Json::Str(d.to_string()))]).dump()
}

fn parse_body(req: &Request) -> Result<Json> {
    let text = std::str::from_utf8(&req.body).context("body must be UTF-8 JSON")?;
    if text.trim().is_empty() {
        return Ok(Json::obj(vec![]));
    }
    Json::parse(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_args_defaults_mirror_generate() {
        let args = Args::parse(std::iter::empty());
        let cfg = CoordinateConfig::from_args(&args);
        assert_eq!(cfg.bind, "127.0.0.1:7171");
        assert_eq!(cfg.spec, JobSpec::default());
        assert_eq!(cfg.shards, cfg.spec.threads, "--shards defaults to the spec's threads");
        assert_eq!(cfg.lease.lease_ms, 30_000);
        assert_eq!(cfg.lease.max_attempts, 3);
        assert_eq!(cfg.lease.backoff_ms, 500);
    }

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            "coordinate --port 0 --count 8 --threads 2 --shards 3 --lease-ms 2000 \
             --max-attempts 5 --backoff-ms 50"
                .split_whitespace()
                .map(str::to_string),
        );
        let cfg = CoordinateConfig::from_args(&args);
        assert_eq!(cfg.bind, "127.0.0.1:0");
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.spec.count, 8);
        assert_eq!(cfg.lease.lease_ms, 2_000);
        assert_eq!(cfg.lease.max_attempts, 5);
        assert_eq!(cfg.lease.backoff_ms, 50);
    }
}
