//! `skr work` — the solving side of a distributed run.
//!
//! A worker joins a coordinator (`--join HOST:PORT`), downloads the run
//! plan, and then pulls shard leases until the coordinator reports the run
//! finished. Each leased shard is solved with [`solve_stream`] — fresh
//! [`Recycler`]/[`Workspace`]/symbolic state per shard, systems regenerated
//! on demand from the family's deterministic per-id RNG streams — i.e. the
//! exact computation a single-node worker thread performs for the same
//! shard, so the streamed-back solutions and [`SolveCounters`] are
//! bit-identical to `skr generate`.
//!
//! While a shard solves, a background thread renews the lease at a third of
//! the lease interval; if the worker dies, the heartbeats stop and the
//! coordinator re-grants the shard to someone else.
//!
//! [`Recycler`]: crate::solver::Recycler
//! [`Workspace`]: crate::solver::Workspace
//! [`SolveCounters`]: crate::solver::SolveCounters

use super::protocol::{shard_checksum, ShardResultMsg, SystemResult, PROTOCOL_VERSION};
use crate::pde::ProblemFamily;
use crate::service::http;
use crate::service::JobSpec;
use crate::solver::{solve_stream, SequenceReuse};
use crate::util::args::Args;
use crate::util::json::Json;
use crate::util::prng::Rng;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Coordinator address, e.g. `127.0.0.1:7171`.
    pub join: String,
    /// Worker name reported with every lease/heartbeat/result.
    pub name: String,
}

impl WorkerConfig {
    pub fn from_args(args: &Args) -> Result<WorkerConfig> {
        let join = args
            .get("join")
            .context("skr work requires --join HOST:PORT (the coordinator address)")?
            .to_string();
        Ok(WorkerConfig {
            join,
            name: args.str_or("name", &format!("w{}", std::process::id())),
        })
    }
}

/// Join a coordinator and solve leases until the run finishes.
pub fn work(cfg: &WorkerConfig) -> Result<()> {
    let plan = fetch_plan(&cfg.join)?;
    let version = plan.get("version").and_then(|v| v.as_usize());
    if version != Some(PROTOCOL_VERSION) {
        bail!(
            "coordinator speaks dist protocol {version:?}, this worker speaks {PROTOCOL_VERSION}"
        );
    }
    let spec = JobSpec::from_json(plan.get("spec").context("plan missing \"spec\"")?)?;
    let pcfg = spec.to_config()?;
    let family = pcfg.family.build_with(pcfg.unknowns, pcfg.grf_alpha);
    let master = Rng::new(pcfg.seed);
    println!(
        "worker {} joined {} ({} count={} n={} seed={})",
        cfg.name,
        cfg.join,
        family.name(),
        pcfg.count,
        pcfg.unknowns,
        pcfg.seed
    );

    let mut completed = 0usize;
    loop {
        let body = Json::obj(vec![("worker", Json::Str(cfg.name.clone()))]).dump();
        let lease = match http::request(&cfg.join, "POST", "/lease", Some(&body)) {
            Ok((200, text)) => Json::parse(&text)?,
            Ok((status, text)) => bail!("lease request answered {status}: {text}"),
            Err(e) => {
                if completed > 0 {
                    // The coordinator finalizes and exits shortly after the
                    // last shard lands — a dead socket after successful
                    // round-trips is the normal end of a run.
                    println!(
                        "worker {}: coordinator gone after {completed} shard(s); exiting",
                        cfg.name
                    );
                    return Ok(());
                }
                return Err(e.context("requesting a lease"));
            }
        };
        match lease.get("grant").and_then(|g| g.as_str()) {
            Some("finished") => {
                println!("worker {}: run finished ({completed} shard(s) accepted)", cfg.name);
                return Ok(());
            }
            Some("wait") => {
                let ms = lease.get("retry_ms").and_then(|v| v.as_usize()).unwrap_or(250);
                std::thread::sleep(Duration::from_millis(ms as u64));
            }
            Some("lease") => {
                if solve_lease(cfg, family.as_ref(), &pcfg, &master, &lease)? {
                    completed += 1;
                }
            }
            other => bail!("unexpected grant {other:?} from coordinator"),
        }
    }
}

/// `GET /plan` with a short connect-retry window so a worker started a
/// moment before its coordinator still joins.
fn fetch_plan(join: &str) -> Result<Json> {
    let mut last = None;
    for _ in 0..20 {
        match http::request(join, "GET", "/plan", None) {
            Ok((200, text)) => return Json::parse(&text),
            Ok((status, text)) => bail!("GET /plan answered {status}: {text}"),
            Err(e) => last = Some(e),
        }
        std::thread::sleep(Duration::from_millis(250));
    }
    Err(last.unwrap_or_else(|| anyhow::anyhow!("no attempts made")))
        .with_context(|| format!("joining coordinator at {join}"))
}

/// Solve one leased shard and post the result. Returns whether the
/// coordinator accepted it (stale/duplicate submissions are discarded
/// server-side and are not an error here).
fn solve_lease(
    cfg: &WorkerConfig,
    family: &dyn ProblemFamily,
    pcfg: &crate::coordinator::PipelineConfig,
    master: &Rng,
    lease: &Json,
) -> Result<bool> {
    let num = |key: &str| -> Result<usize> {
        lease.get(key).and_then(|v| v.as_usize()).with_context(|| format!("lease missing {key:?}"))
    };
    let shard = num("shard")?;
    let attempt = num("attempt")? as u32;
    let lease_ms = lease.get("lease_ms").and_then(|v| v.as_usize()).unwrap_or(30_000) as u64;
    let ids: Vec<usize> = lease
        .get("ids")
        .and_then(|v| v.as_arr())
        .context("lease missing \"ids\"")?
        .iter()
        .map(|v| v.as_usize().context("lease ids must be integers"))
        .collect::<Result<_>>()?;
    println!("lease shard {shard} attempt {attempt} ({} systems)", ids.len());

    // Renew the lease in the background while the shard solves; a killed
    // worker stops heartbeating and the coordinator re-grants after the
    // lease lapses.
    let stop = Arc::new(AtomicBool::new(false));
    let heartbeat = {
        let stop = Arc::clone(&stop);
        let join_addr = cfg.join.clone();
        let body = Json::obj(vec![
            ("shard", Json::Num(shard as f64)),
            ("attempt", Json::Num(attempt as f64)),
            ("worker", Json::Str(cfg.name.clone())),
        ])
        .dump();
        std::thread::spawn(move || {
            let interval = Duration::from_millis((lease_ms / 3).max(100));
            let mut since_beat = Duration::ZERO;
            let tick = Duration::from_millis(50);
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(tick);
                since_beat += tick;
                if since_beat >= interval {
                    since_beat = Duration::ZERO;
                    let _ = http::request(&join_addr, "POST", "/heartbeat", Some(&body));
                }
            }
        })
    };

    let solved = (|| -> Result<(Vec<SystemResult>, SequenceReuse)> {
        let mut systems: Vec<SystemResult> = Vec::with_capacity(ids.len());
        let reuse = solve_stream(
            &ids,
            |id| family.sample(id, &mut master.split(id as u64)),
            pcfg.engine,
            pcfg.precond,
            &pcfg.solver,
            |sys, solution, stats| {
                let input = family.input_field(&sys);
                systems.push(SystemResult { id: sys.id, input, solution, stats });
                Ok(())
            },
        )?;
        Ok((systems, reuse))
    })();
    stop.store(true, Ordering::Relaxed);
    let _ = heartbeat.join();
    let (systems, reuse) = solved?;

    let msg = ShardResultMsg {
        shard,
        attempt,
        worker: cfg.name.clone(),
        checksum: shard_checksum(&systems),
        counters: reuse.counters,
        sparsity_reuse: reuse.sparsity_reuse,
        symbolic_reuse: reuse.symbolic_reuse,
        workspace_reuse: reuse.workspace_reuse,
        systems,
    };
    let path = format!("/shards/{shard}/result");
    let (status, text) = match http::request(&cfg.join, "POST", &path, Some(&msg.to_json().dump()))
    {
        Ok(r) => r,
        Err(e) => {
            // Non-fatal: the run may already have completed via another
            // lease; the next /lease round-trip decides whether to exit.
            eprintln!("worker {}: posting shard {shard} failed: {e:#}", cfg.name);
            return Ok(false);
        }
    };
    let disposition = Json::parse(&text)
        .ok()
        .and_then(|j| j.get("disposition").and_then(|d| d.as_str()).map(str::to_string));
    match (status, disposition.as_deref()) {
        (200, Some("accepted")) => {
            println!("shard {shard} attempt {attempt}: accepted ({} systems)", msg.systems.len());
            Ok(true)
        }
        (200, Some(other)) => {
            println!("shard {shard} attempt {attempt}: {other} — discarded by coordinator");
            Ok(false)
        }
        _ => {
            eprintln!(
                "worker {}: shard {shard} rejected ({status}): {text}",
                cfg.name
            );
            Ok(false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_args_requires_join() {
        let args = Args::parse(std::iter::empty());
        assert!(WorkerConfig::from_args(&args).is_err());
        let args = Args::parse(
            "work --join 127.0.0.1:7171".split_whitespace().map(str::to_string),
        );
        let cfg = WorkerConfig::from_args(&args).unwrap();
        assert_eq!(cfg.join, "127.0.0.1:7171");
        assert!(cfg.name.starts_with('w'), "default name {:?} is pid-derived", cfg.name);
        let args = Args::parse(
            "work --join h:1 --name alice".split_whitespace().map(str::to_string),
        );
        assert_eq!(WorkerConfig::from_args(&args).unwrap().name, "alice");
    }
}
