//! Reusable Krylov solver workspace.
//!
//! One [`Workspace`] is owned by the sequence driver (`solve_sequence`, the
//! pipeline workers) and threaded through every solve of a shard, so the
//! Krylov basis vectors, Hessenberg storage, Givens arrays and the residual /
//! correction scratch are allocated once for the first system and reused for
//! the rest — steady-state solves perform no Krylov-basis or Hessenberg
//! allocations. Buffers are pooled, never zeroed wholesale: the solvers
//! already fully (re)initialise every location they read, which is what keeps
//! pooled and fresh-buffer runs bit-identical.

/// Deterministic operation counters accumulated across the solves of a
/// sequence — the bit-stable backbone of `skr bench` regression gating.
///
/// Unlike wall-clock timings these are pure *counts* of the work performed
/// (operator applies, preconditioner applies, orthogonalization flops,
/// recycle-space events), so two runs of the same workload with the same
/// seeds produce identical values even on noisy CI runners. The solvers
/// increment them inline; the costs of the small dense eigenproblems /
/// QR factorizations (O(m³), independent of n) are deliberately excluded.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SolveCounters {
    /// Sparse operator applies A·v (including residual recomputations).
    pub matvecs: u64,
    /// Preconditioner applies z = M⁻¹ r.
    pub precond_applies: u64,
    /// Flops spent keeping Krylov bases orthogonal: CGS2 Arnoldi
    /// orthogonalization, projections against the recycle space C during
    /// deflated Arnoldi, and basis normalizations (see [`cgs2_flops`] /
    /// [`proj_flops`] for the exact accounting).
    pub ortho_flops: u64,
    /// Recycle spaces re-orthonormalized for a *changed* operator
    /// (the k reseed operator applies were paid).
    pub recycle_reseeds: u64,
    /// Recycle spaces carried verbatim because the operator fingerprint
    /// matched (the reseed applies were skipped — the cheap hit).
    pub recycle_carries: u64,
    /// Harmonic-Ritz harvests that installed a fresh recycle space.
    pub harvests: u64,
}

impl SolveCounters {
    /// Accumulate another tally (multi-worker reduction).
    pub fn merge(&mut self, other: &SolveCounters) {
        self.matvecs += other.matvecs;
        self.precond_applies += other.precond_applies;
        self.ortho_flops += other.ortho_flops;
        self.recycle_reseeds += other.recycle_reseeds;
        self.recycle_carries += other.recycle_carries;
        self.harvests += other.harvests;
    }

    /// Recycle-subspace installs of either flavour.
    pub fn recycle_installs(&self) -> u64 {
        self.recycle_reseeds + self.recycle_carries
    }

    /// `(name, value)` view in a fixed order — drives the `BENCH_*.json`
    /// counter block and the per-field regression check.
    pub fn fields(&self) -> [(&'static str, u64); 6] {
        [
            ("matvecs", self.matvecs),
            ("precond_applies", self.precond_applies),
            ("ortho_flops", self.ortho_flops),
            ("recycle_reseeds", self.recycle_reseeds),
            ("recycle_carries", self.recycle_carries),
            ("harvests", self.harvests),
        ]
    }
}

/// Flops charged for one CGS2 (two-pass classical Gram-Schmidt)
/// orthogonalization of a length-`n` vector against `blen` basis vectors
/// plus the trailing normalization: two passes of `blen` dots + `blen`
/// axpys (2n flops each) and one norm + scale.
pub(crate) fn cgs2_flops(blen: usize, n: usize) -> u64 {
    (8 * blen * n + 3 * n) as u64
}

/// Flops charged for a one-pass projection against `cols` orthonormal
/// columns (one dot + one axpy per column).
pub(crate) fn proj_flops(cols: usize, n: usize) -> u64 {
    (4 * cols * n) as u64
}

/// Pooled buffers shared by `gmres_ws` and `gcrodr_ws`.
#[derive(Debug, Default)]
pub struct Workspace {
    pub(crate) n: usize,
    pub(crate) m: usize,
    /// Operator-apply output / Arnoldi candidate vector.
    pub(crate) w: Vec<f64>,
    /// Preconditioner-apply output.
    pub(crate) z: Vec<f64>,
    /// Residual.
    pub(crate) r: Vec<f64>,
    /// Correction accumulator (V y and recycle updates).
    pub(crate) du: Vec<f64>,
    /// Triangular-solve solution.
    pub(crate) y: Vec<f64>,
    /// Column-major (m+1) × m Hessenberg.
    pub(crate) h: Vec<f64>,
    /// Givens cosines.
    pub(crate) cs: Vec<f64>,
    /// Givens sines.
    pub(crate) sn: Vec<f64>,
    /// Rotated right-hand side of the least-squares problem.
    pub(crate) g: Vec<f64>,
    /// Krylov basis pool; logical length is tracked per solve, the vectors
    /// persist across solves.
    pub(crate) basis: Vec<Vec<f64>>,
    /// Deterministic op counters, accumulated across every solve that runs on
    /// this workspace; reset explicitly via [`Workspace::reset_counters`].
    pub(crate) ctr: SolveCounters,
    prepared: bool,
    reuse_count: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Size the buffers for an (n, m) solve. Returns `true` when the shapes
    /// matched the previous solve and every buffer (including the basis pool)
    /// was reused as-is.
    pub(crate) fn prepare(&mut self, n: usize, m: usize) -> bool {
        let reused = self.prepared && self.n == n && self.m == m;
        if reused {
            self.reuse_count += 1;
        } else {
            self.n = n;
            self.m = m;
            self.w = vec![0.0; n];
            self.z = vec![0.0; n];
            self.r = vec![0.0; n];
            self.du = vec![0.0; n];
            self.y = vec![0.0; m];
            self.h = vec![0.0; (m + 1) * m];
            self.cs = vec![0.0; m];
            self.sn = vec![0.0; m];
            self.g = vec![0.0; m + 1];
            self.basis.clear();
            self.prepared = true;
        }
        reused
    }

    /// How many solves reused the buffers without reallocation.
    pub fn reuse_count(&self) -> usize {
        self.reuse_count
    }

    /// Deterministic operation counters accumulated so far.
    pub fn counters(&self) -> &SolveCounters {
        &self.ctr
    }

    /// Zero the counters (between benchmark repetitions) without touching the
    /// pooled buffers.
    pub fn reset_counters(&mut self) {
        self.ctr = SolveCounters::default();
    }
}

/// Append `scale * src` as the next pooled basis vector, allocating only if
/// the pool has never been this deep.
pub(crate) fn pool_push_scaled(
    pool: &mut Vec<Vec<f64>>,
    blen: &mut usize,
    src: &[f64],
    scale: f64,
) {
    if pool.len() == *blen {
        pool.push(vec![0.0; src.len()]);
    }
    for (d, s) in pool[*blen].iter_mut().zip(src) {
        *d = s * scale;
    }
    *blen += 1;
}

/// Append `src / denom` as the next pooled basis vector. Kept distinct from
/// [`pool_push_scaled`]: `s / d` and `s * (1.0 / d)` round differently, and
/// each solver must keep its historical arithmetic bit-for-bit.
pub(crate) fn pool_push_div(pool: &mut Vec<Vec<f64>>, blen: &mut usize, src: &[f64], denom: f64) {
    if pool.len() == *blen {
        pool.push(vec![0.0; src.len()]);
    }
    for (d, s) in pool[*blen].iter_mut().zip(src) {
        *d = s / denom;
    }
    *blen += 1;
}

/// Append a copy of `src` as the next pooled basis vector.
pub(crate) fn pool_push_copy(pool: &mut Vec<Vec<f64>>, blen: &mut usize, src: &[f64]) {
    if pool.len() == *blen {
        pool.push(vec![0.0; src.len()]);
    }
    pool[*blen].copy_from_slice(src);
    *blen += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_reuses_matching_shapes() {
        let mut ws = Workspace::new();
        assert!(!ws.prepare(10, 5));
        assert!(ws.prepare(10, 5));
        assert!(!ws.prepare(10, 6));
        assert!(!ws.prepare(12, 6));
        assert!(ws.prepare(12, 6));
        assert_eq!(ws.reuse_count(), 2);
        assert_eq!(ws.w.len(), 12);
        assert_eq!(ws.h.len(), 7 * 6);
    }

    #[test]
    fn counters_merge_and_enumerate() {
        let mut a = SolveCounters {
            matvecs: 3,
            precond_applies: 2,
            ortho_flops: 100,
            recycle_reseeds: 1,
            recycle_carries: 4,
            harvests: 5,
        };
        let b = SolveCounters {
            matvecs: 10,
            precond_applies: 20,
            ortho_flops: 1000,
            recycle_reseeds: 2,
            recycle_carries: 1,
            harvests: 0,
        };
        a.merge(&b);
        assert_eq!(a.matvecs, 13);
        assert_eq!(a.ortho_flops, 1100);
        assert_eq!(a.recycle_installs(), 8);
        let names: Vec<&str> = a.fields().iter().map(|(n, _)| *n).collect();
        assert_eq!(
            names,
            [
                "matvecs",
                "precond_applies",
                "ortho_flops",
                "recycle_reseeds",
                "recycle_carries",
                "harvests"
            ]
        );
        assert_eq!(a.fields()[0].1, 13);
    }

    #[test]
    fn flop_models_scale_with_basis_and_length() {
        assert_eq!(cgs2_flops(0, 10), 30); // pure normalization
        assert_eq!(cgs2_flops(5, 10), 8 * 5 * 10 + 30);
        assert_eq!(proj_flops(3, 10), 120);
    }

    #[test]
    fn pool_grows_then_recycles() {
        let mut pool: Vec<Vec<f64>> = Vec::new();
        let mut blen = 0;
        pool_push_scaled(&mut pool, &mut blen, &[2.0, 4.0], 0.5);
        pool_push_copy(&mut pool, &mut blen, &[3.0, 5.0]);
        assert_eq!(blen, 2);
        assert_eq!(pool[0], vec![1.0, 2.0]);
        assert_eq!(pool[1], vec![3.0, 5.0]);
        // Next solve resets the logical length; the allocations persist.
        blen = 0;
        pool_push_copy(&mut pool, &mut blen, &[7.0, 8.0]);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool[0], vec![7.0, 8.0]);
    }
}
