//! Reusable Krylov solver workspace.
//!
//! One [`Workspace`] is owned by the sequence driver (`solve_sequence`, the
//! pipeline workers) and threaded through every solve of a shard, so the
//! Krylov basis vectors, Hessenberg storage, Givens arrays and the residual /
//! correction scratch are allocated once for the first system and reused for
//! the rest — steady-state solves perform no Krylov-basis or Hessenberg
//! allocations. Buffers are pooled, never zeroed wholesale: the solvers
//! already fully (re)initialise every location they read, which is what keeps
//! pooled and fresh-buffer runs bit-identical.

/// Pooled buffers shared by `gmres_ws` and `gcrodr_ws`.
#[derive(Debug, Default)]
pub struct Workspace {
    pub(crate) n: usize,
    pub(crate) m: usize,
    /// Operator-apply output / Arnoldi candidate vector.
    pub(crate) w: Vec<f64>,
    /// Preconditioner-apply output.
    pub(crate) z: Vec<f64>,
    /// Residual.
    pub(crate) r: Vec<f64>,
    /// Correction accumulator (V y and recycle updates).
    pub(crate) du: Vec<f64>,
    /// Triangular-solve solution.
    pub(crate) y: Vec<f64>,
    /// Column-major (m+1) × m Hessenberg.
    pub(crate) h: Vec<f64>,
    /// Givens cosines.
    pub(crate) cs: Vec<f64>,
    /// Givens sines.
    pub(crate) sn: Vec<f64>,
    /// Rotated right-hand side of the least-squares problem.
    pub(crate) g: Vec<f64>,
    /// Krylov basis pool; logical length is tracked per solve, the vectors
    /// persist across solves.
    pub(crate) basis: Vec<Vec<f64>>,
    prepared: bool,
    reuse_count: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Size the buffers for an (n, m) solve. Returns `true` when the shapes
    /// matched the previous solve and every buffer (including the basis pool)
    /// was reused as-is.
    pub(crate) fn prepare(&mut self, n: usize, m: usize) -> bool {
        let reused = self.prepared && self.n == n && self.m == m;
        if reused {
            self.reuse_count += 1;
        } else {
            self.n = n;
            self.m = m;
            self.w = vec![0.0; n];
            self.z = vec![0.0; n];
            self.r = vec![0.0; n];
            self.du = vec![0.0; n];
            self.y = vec![0.0; m];
            self.h = vec![0.0; (m + 1) * m];
            self.cs = vec![0.0; m];
            self.sn = vec![0.0; m];
            self.g = vec![0.0; m + 1];
            self.basis.clear();
            self.prepared = true;
        }
        reused
    }

    /// How many solves reused the buffers without reallocation.
    pub fn reuse_count(&self) -> usize {
        self.reuse_count
    }
}

/// Append `scale * src` as the next pooled basis vector, allocating only if
/// the pool has never been this deep.
pub(crate) fn pool_push_scaled(
    pool: &mut Vec<Vec<f64>>,
    blen: &mut usize,
    src: &[f64],
    scale: f64,
) {
    if pool.len() == *blen {
        pool.push(vec![0.0; src.len()]);
    }
    for (d, s) in pool[*blen].iter_mut().zip(src) {
        *d = s * scale;
    }
    *blen += 1;
}

/// Append `src / denom` as the next pooled basis vector. Kept distinct from
/// [`pool_push_scaled`]: `s / d` and `s * (1.0 / d)` round differently, and
/// each solver must keep its historical arithmetic bit-for-bit.
pub(crate) fn pool_push_div(pool: &mut Vec<Vec<f64>>, blen: &mut usize, src: &[f64], denom: f64) {
    if pool.len() == *blen {
        pool.push(vec![0.0; src.len()]);
    }
    for (d, s) in pool[*blen].iter_mut().zip(src) {
        *d = s / denom;
    }
    *blen += 1;
}

/// Append a copy of `src` as the next pooled basis vector.
pub(crate) fn pool_push_copy(pool: &mut Vec<Vec<f64>>, blen: &mut usize, src: &[f64]) {
    if pool.len() == *blen {
        pool.push(vec![0.0; src.len()]);
    }
    pool[*blen].copy_from_slice(src);
    *blen += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_reuses_matching_shapes() {
        let mut ws = Workspace::new();
        assert!(!ws.prepare(10, 5));
        assert!(ws.prepare(10, 5));
        assert!(!ws.prepare(10, 6));
        assert!(!ws.prepare(12, 6));
        assert!(ws.prepare(12, 6));
        assert_eq!(ws.reuse_count(), 2);
        assert_eq!(ws.w.len(), 12);
        assert_eq!(ws.h.len(), 7 * 6);
    }

    #[test]
    fn pool_grows_then_recycles() {
        let mut pool: Vec<Vec<f64>> = Vec::new();
        let mut blen = 0;
        pool_push_scaled(&mut pool, &mut blen, &[2.0, 4.0], 0.5);
        pool_push_copy(&mut pool, &mut blen, &[3.0, 5.0]);
        assert_eq!(blen, 2);
        assert_eq!(pool[0], vec![1.0, 2.0]);
        assert_eq!(pool[1], vec![3.0, 5.0]);
        // Next solve resets the logical length; the allocations persist.
        blen = 0;
        pool_push_copy(&mut pool, &mut blen, &[7.0, 8.0]);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool[0], vec![7.0, 8.0]);
    }
}
