//! Solve statistics and configuration shared by GMRES and GCRO-DR.

use anyhow::{bail, Result};

/// Why a solve stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// Relative residual dropped below tolerance.
    Converged,
    /// Hit the iteration cap without converging (the paper's Fig-13
    /// stability metric counts these).
    MaxIters,
    /// Lucky or unlucky exact breakdown in the Arnoldi process.
    Breakdown,
}

impl StopReason {
    /// Stable machine-readable tag (the JSONL trace `stop` field).
    pub fn label(&self) -> &'static str {
        match self {
            StopReason::Converged => "converged",
            StopReason::MaxIters => "max_iters",
            StopReason::Breakdown => "breakdown",
        }
    }

    /// Inverse of [`StopReason::label`] — decodes the tag off the wire
    /// (trace files, the dist shard-result protocol).
    pub fn parse(s: &str) -> Result<StopReason> {
        Ok(match s {
            "converged" => StopReason::Converged,
            "max_iters" => StopReason::MaxIters,
            "breakdown" => StopReason::Breakdown,
            other => bail!("unknown stop reason {other:?}"),
        })
    }
}

/// Per-system solve outcome.
#[derive(Debug, Clone)]
pub struct SolveStats {
    /// Inner (matrix-vector product) iterations performed.
    pub iters: usize,
    /// Wall-clock seconds for this system.
    pub seconds: f64,
    /// Final relative residual ‖b − Ax‖ / ‖b‖.
    pub rel_residual: f64,
    pub stop: StopReason,
    /// Optional residual trace: (cumulative iters, relative residual) pairs
    /// recorded at each restart/cycle boundary — drives Figs 1/11/12.
    pub trace: Vec<(usize, f64)>,
}

impl SolveStats {
    pub fn converged(&self) -> bool {
        self.stop == StopReason::Converged
    }
}

/// Shared solver configuration.
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Relative residual tolerance.
    pub tol: f64,
    /// Max inner iterations per system (paper: 10⁴).
    pub max_iters: usize,
    /// Krylov cycle length m (PETSc GMRES restart default: 30).
    pub m: usize,
    /// Recycle-space dimension k (GCRO-DR only).
    pub k: usize,
    /// Record a residual trace (slightly more bookkeeping).
    pub record_trace: bool,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig { tol: 1e-8, max_iters: 10_000, m: 30, k: 10, record_trace: false }
    }
}

impl SolverConfig {
    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_m(mut self, m: usize) -> Self {
        self.m = m;
        self
    }

    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn with_max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    pub fn with_trace(mut self, record: bool) -> Self {
        self.record_trace = record;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_reason_label_round_trips() {
        for stop in [StopReason::Converged, StopReason::MaxIters, StopReason::Breakdown] {
            assert_eq!(StopReason::parse(stop.label()).unwrap(), stop);
        }
        assert!(StopReason::parse("exploded").is_err());
    }
}
