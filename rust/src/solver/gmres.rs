//! Restarted, right-preconditioned GMRES(m) — the paper's baseline
//! (PETSc's default KSP for nonsymmetric systems, restart 30).
//!
//! Iterates on A M⁻¹ u = b with x = M⁻¹ u, so the *true* residual norm is
//! available directly from the least-squares problem and tolerance semantics
//! match PETSc's `KSPSetTolerances(rtol)`.
//!
//! All scratch (Krylov basis, Hessenberg, Givens arrays, residual and
//! correction vectors) lives in a [`Workspace`]; sequence drivers pass one
//! workspace through every solve so steady-state solves allocate nothing.
//! Pooled buffers are fully (re)initialised before any read, so workspace
//! reuse is bit-identical to fresh allocation.

use super::workspace::{cgs2_flops, pool_push_copy, pool_push_scaled, Workspace};
use crate::la::{axpy, norm2, Csr};
use crate::obs::{NoopObserver, SolveObserver};
use crate::precond::Preconditioner;
use crate::solver::stats::{SolveStats, SolverConfig, StopReason};
use crate::util::timer::Timer;

/// Solve A x = b. `x` carries the initial guess in and the solution out.
pub fn gmres(
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    m_inv: &dyn Preconditioner,
    cfg: &SolverConfig,
) -> SolveStats {
    gmres_observed(a, b, x, m_inv, cfg, &mut NoopObserver)
}

/// [`gmres`] with iteration-level observability: `obs` receives cycle
/// residuals and the final outcome. The observer only ever reads copies of
/// solver state, so the arithmetic (and therefore iteration counts and the
/// solution) is bit-identical to the unobserved path.
pub fn gmres_observed(
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    m_inv: &dyn Preconditioner,
    cfg: &SolverConfig,
    obs: &mut dyn SolveObserver,
) -> SolveStats {
    gmres_ws(a, b, x, m_inv, cfg, obs, &mut Workspace::new())
}

/// [`gmres_observed`] on a caller-owned [`Workspace`]. When the workspace's
/// shapes match the previous solve every buffer — including the Krylov basis
/// pool and the Hessenberg — is reused without reallocation.
pub fn gmres_ws(
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    m_inv: &dyn Preconditioner,
    cfg: &SolverConfig,
    obs: &mut dyn SolveObserver,
    ws: &mut Workspace,
) -> SolveStats {
    let timer = Timer::start();
    let n = b.len();
    let m = cfg.m.max(1);
    let bnorm = norm2(b).max(1e-300);

    let mut trace = Vec::new();
    let mut total_iters = 0usize;

    ws.prepare(n, m);
    let Workspace { basis, h, cs, sn, g, w, z, r, du, y, ctr, .. } = ws;

    let mut rel = {
        r.copy_from_slice(b);
        a.matvec_into(x, w);
        ctr.matvecs += 1;
        axpy(-1.0, w, r);
        norm2(r) / bnorm
    };
    obs.on_start(n, rel);
    if cfg.record_trace {
        trace.push((0, rel));
    }
    if rel < cfg.tol {
        let stats = SolveStats {
            iters: 0,
            seconds: timer.secs(),
            rel_residual: rel,
            stop: StopReason::Converged,
            trace,
        };
        obs.on_end(&stats);
        return stats;
    }

    'restart: loop {
        // r = b - A x
        r.copy_from_slice(b);
        a.matvec_into(x, w);
        ctr.matvecs += 1;
        axpy(-1.0, w, r);
        let beta = norm2(r);
        rel = beta / bnorm;
        if rel < cfg.tol {
            break 'restart;
        }
        // Logical basis length; the pooled vectors behind it persist across
        // restarts and across solves.
        let mut blen = 0usize;
        pool_push_scaled(basis, &mut blen, r, 1.0 / beta);
        g.iter_mut().for_each(|v| *v = 0.0);
        g[0] = beta;
        let mut j_done = 0usize;

        for j in 0..m {
            // w = A M⁻¹ v_j
            m_inv.apply(&basis[j], z);
            a.matvec_into(z, w);
            ctr.precond_applies += 1;
            ctr.matvecs += 1;
            total_iters += 1;
            // Arnoldi (MGS + DGKS).
            ctr.ortho_flops += cgs2_flops(blen, n);
            let coeffs = crate::la::ortho::cgs2_orthogonalize(w, &basis[..blen]);
            for (i, c) in coeffs.iter().enumerate() {
                h[j * (m + 1) + i] = *c;
            }
            let hnext = crate::la::ortho::normalize(w);
            h[j * (m + 1) + j + 1] = hnext;
            let breakdown = hnext < 1e-14 * bnorm;
            if !breakdown {
                pool_push_copy(basis, &mut blen, w);
            }
            // Apply stored Givens rotations to the new column.
            let col = &mut h[j * (m + 1)..j * (m + 1) + m + 1];
            for i in 0..j {
                let (c, s) = (cs[i], sn[i]);
                let (t0, t1) = (col[i], col[i + 1]);
                col[i] = c * t0 + s * t1;
                col[i + 1] = -s * t0 + c * t1;
            }
            // New rotation zeroing col[j+1].
            let (t0, t1) = (col[j], col[j + 1]);
            let rho = t0.hypot(t1);
            let (c, s) = if rho == 0.0 { (1.0, 0.0) } else { (t0 / rho, t1 / rho) };
            cs[j] = c;
            sn[j] = s;
            col[j] = rho;
            col[j + 1] = 0.0;
            let (g0, g1) = (g[j], g[j + 1]);
            g[j] = c * g0 + s * g1;
            g[j + 1] = -s * g0 + c * g1;

            j_done = j + 1;
            rel = g[j + 1].abs() / bnorm;
            if rel < cfg.tol || total_iters >= cfg.max_iters || breakdown {
                break;
            }
        }

        // y solves the triangular system R y = g (first j_done rows). A
        // (near-)zero diagonal means the Krylov space hit an invariant
        // subspace of a singular operator: the component is indeterminate,
        // so take 0 (minimum-norm choice) rather than dividing by zero.
        // Every y[i] is written before it is read, so the pooled buffer
        // needs no clearing.
        let y = &mut y[..j_done];
        for i in (0..j_done).rev() {
            let mut s = g[i];
            for l in i + 1..j_done {
                s -= h[l * (m + 1) + i] * y[l];
            }
            let d = h[i * (m + 1) + i];
            y[i] = if d.abs() > 1e-300 { s / d } else { 0.0 };
        }
        // x += M⁻¹ (V y)
        du.fill(0.0);
        for (l, yl) in y.iter().enumerate() {
            axpy(*yl, &basis[l], du);
        }
        m_inv.apply(du, z);
        ctr.precond_applies += 1;
        axpy(1.0, z, x);

        obs.on_cycle(total_iters, rel);
        if cfg.record_trace {
            trace.push((total_iters, rel));
        }
        if rel < cfg.tol {
            break 'restart;
        }
        if total_iters >= cfg.max_iters {
            // Recompute the true residual for honest reporting.
            r.copy_from_slice(b);
            a.matvec_into(x, w);
            ctr.matvecs += 1;
            axpy(-1.0, w, r);
            let stats = SolveStats {
                iters: total_iters,
                seconds: timer.secs(),
                rel_residual: norm2(r) / bnorm,
                stop: StopReason::MaxIters,
                trace,
            };
            obs.on_end(&stats);
            return stats;
        }
    }

    // True residual on exit — convergence is only claimed when the honest
    // residual agrees (a breakdown on a singular operator can fool the
    // Givens estimate).
    r.copy_from_slice(b);
    a.matvec_into(x, w);
    ctr.matvecs += 1;
    axpy(-1.0, w, r);
    let final_rel = norm2(r) / bnorm;
    let stop = if final_rel.is_finite() && final_rel < cfg.tol * 1.5 {
        StopReason::Converged
    } else {
        StopReason::Breakdown
    };
    let stats = SolveStats {
        iters: total_iters,
        seconds: timer.secs(),
        rel_residual: final_rel,
        stop,
        trace,
    };
    obs.on_end(&stats);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};
    use crate::precond::{Identity, Ilu0, Jacobi, PrecondKind};
    use crate::util::prng::Rng;

    fn solve_and_check(a: &Csr, cfg: &SolverConfig, p: &dyn Preconditioner) -> SolveStats {
        let n = a.nrows();
        let mut rng = Rng::new(77);
        let xtrue = rng.normals(n);
        let b = a.matvec(&xtrue);
        let mut x = vec![0.0; n];
        let stats = gmres(a, &b, &mut x, p, cfg);
        assert!(stats.converged(), "{stats:?}");
        assert!(stats.rel_residual <= cfg.tol * 1.01, "resid {}", stats.rel_residual);
        stats
    }

    #[test]
    fn converges_on_spd() {
        let a = lap1d(100);
        solve_and_check(&a, &SolverConfig::default().with_tol(1e-10), &Identity);
    }

    #[test]
    fn converges_on_nonsymmetric() {
        let a = nonsym(200);
        solve_and_check(&a, &SolverConfig::default().with_tol(1e-9), &Identity);
    }

    #[test]
    fn preconditioning_reduces_iterations() {
        let a = lap1d(400);
        let cfg = SolverConfig::default().with_tol(1e-8).with_m(30);
        let plain = solve_and_check(&a, &cfg, &Identity);
        let ilu = Ilu0::new(&a).unwrap();
        let pre = solve_and_check(&a, &cfg, &ilu);
        assert!(
            pre.iters < plain.iters,
            "ILU {} vs none {}",
            pre.iters,
            plain.iters
        );
    }

    #[test]
    fn jacobi_preconditioner_converges() {
        let a = nonsym(150);
        let p = Jacobi::new(&a).unwrap();
        solve_and_check(&a, &SolverConfig::default().with_tol(1e-9), &p);
    }

    #[test]
    fn zero_rhs_converges_instantly() {
        let a = lap1d(10);
        let mut x = vec![0.0; 10];
        let stats = gmres(&a, &[0.0; 10], &mut x, &Identity, &SolverConfig::default());
        assert_eq!(stats.iters, 0);
        assert!(stats.converged());
    }

    #[test]
    fn honors_initial_guess() {
        let a = lap1d(50);
        let mut rng = Rng::new(5);
        let xtrue = rng.normals(50);
        let b = a.matvec(&xtrue);
        // Start at the exact solution: 0 iterations.
        let mut x = xtrue.clone();
        let stats = gmres(&a, &b, &mut x, &Identity, &SolverConfig::default());
        assert_eq!(stats.iters, 0);
    }

    #[test]
    fn max_iters_reported() {
        let a = lap1d(500);
        let mut x = vec![0.0; 500];
        let b = vec![1.0; 500];
        let cfg = SolverConfig::default().with_tol(1e-14).with_max_iters(10).with_m(5);
        let stats = gmres(&a, &b, &mut x, &Identity, &cfg);
        assert_eq!(stats.stop, StopReason::MaxIters);
        assert!(stats.iters <= 11);
    }

    #[test]
    fn all_preconditioners_converge_on_poisson1d() {
        let a = lap1d(128);
        for kind in PrecondKind::ALL {
            let p = kind.build(&a).unwrap();
            let stats = solve_and_check(&a, &SolverConfig::default().with_tol(1e-8), p.as_ref());
            assert!(stats.iters > 0, "{kind:?}");
        }
    }

    #[test]
    fn observer_has_zero_impact_on_numerics() {
        // Acceptance gate: solving with a recording observer must produce
        // bit-identical iteration counts, residuals and solutions to the
        // default no-op path.
        use crate::obs::{RecordingObserver, SolveEvent};
        let a = lap1d(300);
        let b = vec![1.0; 300];
        let cfg = SolverConfig::default().with_tol(1e-10).with_m(20);
        let mut x1 = vec![0.0; 300];
        let s1 = gmres(&a, &b, &mut x1, &Identity, &cfg);
        let mut x2 = vec![0.0; 300];
        let mut obs = RecordingObserver::new();
        let s2 = gmres_observed(&a, &b, &mut x2, &Identity, &cfg, &mut obs);
        assert_eq!(s1.iters, s2.iters);
        assert_eq!(s1.stop, s2.stop);
        assert_eq!(s1.rel_residual.to_bits(), s2.rel_residual.to_bits());
        for (u, v) in x1.iter().zip(&x2) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        // The event stream brackets the solve and ends on the true stats.
        assert!(matches!(obs.events.first(), Some(SolveEvent::Start { .. })));
        match obs.events.last() {
            Some(SolveEvent::End { iters, stop, .. }) => {
                assert_eq!(*iters, s2.iters);
                assert_eq!(*stop, "converged");
            }
            other => panic!("expected End event, got {other:?}"),
        }
        // Cycle events land on cycle boundaries, monotone in iters.
        let cycles = obs.cycles();
        assert!(!cycles.is_empty());
        assert!(cycles.windows(2).all(|w| w[0].0 < w[1].0));
        assert_eq!(cycles.last().unwrap().0, s2.iters);
    }

    #[test]
    fn trace_is_monotone_in_iters() {
        let a = lap1d(300);
        let mut x = vec![0.0; 300];
        let b = vec![1.0; 300];
        let cfg = SolverConfig::default().with_tol(1e-10).with_trace(true);
        let stats = gmres(&a, &b, &mut x, &Identity, &cfg);
        assert!(stats.trace.len() >= 2);
        assert!(stats.trace.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn counters_are_deterministic_and_plausible() {
        let a = lap1d(200);
        let b = vec![1.0; 200];
        let cfg = SolverConfig::default().with_tol(1e-10).with_m(20);
        let run = || {
            let mut ws = Workspace::new();
            let mut x = vec![0.0; 200];
            let s = gmres_ws(&a, &b, &mut x, &Identity, &cfg, &mut NoopObserver, &mut ws);
            (s, *ws.counters())
        };
        let (s1, c1) = run();
        let (_, c2) = run();
        assert_eq!(c1, c2, "counters must be bit-stable across identical solves");
        // One matvec + precond apply per Arnoldi step, plus the initial and
        // final residual computations.
        assert!(c1.matvecs as usize >= s1.iters + 2);
        assert!(c1.precond_applies as usize >= s1.iters);
        assert!(c1.ortho_flops > 0);
        assert_eq!(c1.recycle_installs(), 0);
        assert_eq!(c1.harvests, 0);
    }

    #[test]
    fn workspace_reuse_is_bit_identical() {
        // Acceptance gate: a dirty workspace carried over from a previous
        // solve must not perturb a single bit of the next solve.
        let cfg = SolverConfig::default().with_tol(1e-10).with_m(15);
        let mut ws = Workspace::new();
        for shift in [0.0, 0.1, 0.35] {
            let a = lap1d(220).add_diag(shift);
            let b: Vec<f64> = (0..220).map(|i| (i as f64 * 0.13).sin()).collect();
            let mut x1 = vec![0.0; 220];
            let s1 = gmres(&a, &b, &mut x1, &Identity, &cfg);
            let mut x2 = vec![0.0; 220];
            let s2 = gmres_ws(&a, &b, &mut x2, &Identity, &cfg, &mut NoopObserver, &mut ws);
            assert_eq!(s1.iters, s2.iters);
            assert_eq!(s1.rel_residual.to_bits(), s2.rel_residual.to_bits());
            for (u, v) in x1.iter().zip(&x2) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
        assert_eq!(ws.reuse_count(), 2);
    }
}
