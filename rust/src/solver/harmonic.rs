//! Harmonic-Ritz eigenproblems for GCRO-DR (Parks et al., Alg. 2 lines 14
//! and 29). Both problems are small (≈ m×m) and real; eigenpairs may be
//! complex, so selected eigenvectors are *realified* — complex-conjugate
//! pairs contribute their real and imaginary parts as two real basis
//! vectors, which span the same invariant subspace.

use crate::la::eig::{eig, eig_generalized, smallest_k_indices, Eig};
use crate::la::{Mat, ZMat};
use anyhow::Result;

/// Realify up to `k` eigenvectors with smallest-magnitude eigenvalues into a
/// real `n × k'` matrix (k' ≤ k; conjugate pairs consume two columns).
fn realify_smallest(e: &Eig, k: usize) -> Mat {
    let n = e.vectors.nrows;
    let order = smallest_k_indices(&e.values, e.values.len());
    let mut used = vec![false; e.values.len()];
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(k);
    for &i in &order {
        if cols.len() >= k || used[i] {
            continue;
        }
        used[i] = true;
        let lam = e.values[i];
        let scale_tol = 1e-10 * (1.0 + lam.abs());
        if lam.im.abs() <= scale_tol {
            // Real eigenvalue: take the real part of the vector.
            let mut v: Vec<f64> = (0..n).map(|r| e.vectors[(r, i)].re).collect();
            let nrm = crate::la::norm2(&v);
            if nrm > 1e-300 {
                crate::la::scal(1.0 / nrm, &mut v);
                cols.push(v);
            }
        } else {
            // Complex pair: real + imaginary parts; mark the conjugate used.
            for &j in &order {
                if !used[j] && (e.values[j].conj() - lam).abs() <= 1e-8 * (1.0 + lam.abs()) {
                    used[j] = true;
                    break;
                }
            }
            let mut re: Vec<f64> = (0..n).map(|r| e.vectors[(r, i)].re).collect();
            let mut im: Vec<f64> = (0..n).map(|r| e.vectors[(r, i)].im).collect();
            let nr = crate::la::norm2(&re);
            if nr > 1e-300 {
                crate::la::scal(1.0 / nr, &mut re);
                cols.push(re);
            }
            if cols.len() < k {
                let ni = crate::la::norm2(&im);
                if ni > 1e-300 {
                    crate::la::scal(1.0 / ni, &mut im);
                    cols.push(im);
                }
            }
        }
    }
    let mut p = Mat::zeros(n, cols.len());
    for (j, c) in cols.iter().enumerate() {
        p.set_col(j, c);
    }
    p
}

/// Initial-cycle harmonic Ritz (Alg. 2 line 14): eigenvectors of
/// `H_m + h²_{m+1,m} · H_m^{-H} e_m e_mᵀ` with smallest |θ|.
/// `h_bar` is the (j+1)×j Hessenberg from the GMRES cycle. Returns a j×k'
/// real matrix P.
pub fn harmonic_ritz_initial(h_bar: &Mat, k: usize) -> Result<Mat> {
    let j = h_bar.ncols;
    assert_eq!(h_bar.nrows, j + 1);
    // Square part H_m.
    let mut h = Mat::zeros(j, j);
    for c in 0..j {
        for r in 0..j {
            h[(r, c)] = h_bar[(r, c)];
        }
    }
    let h2 = h_bar[(j, j - 1)] * h_bar[(j, j - 1)];
    // f = H^{-H} e_m  ⇔  Hᵀ f = e_m (real arithmetic).
    let f = h.transpose().solve(&{
        let mut e = vec![0.0; j];
        e[j - 1] = 1.0;
        e
    })?;
    let mut m = h;
    for r in 0..j {
        m[(r, j - 1)] += h2 * f[r];
    }
    let e = eig(&ZMat::from_real(&m))?;
    Ok(realify_smallest(&e, k.min(j.saturating_sub(1)).max(1)))
}

/// Recycling-cycle harmonic Ritz (Alg. 2 line 29): generalized problem
/// `ḠᴴḠ z = θ Ḡᴴ (ŴᴴV̂) z`. All inputs real; returns m×k' real P.
pub fn harmonic_ritz_cycle(g_bar: &Mat, w_h_v: &Mat, k: usize) -> Result<Mat> {
    let m = g_bar.ncols;
    assert_eq!(g_bar.nrows, m + 1);
    assert_eq!(w_h_v.nrows, m + 1);
    assert_eq!(w_h_v.ncols, m);
    let gt = g_bar.transpose();
    let a = gt.matmul(g_bar); // m×m
    let b = gt.matmul(w_h_v); // m×m
    let e = eig_generalized(&ZMat::from_real(&a), &ZMat::from_real(&b))?;
    Ok(realify_smallest(&e, k.min(m.saturating_sub(1)).max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::c64::C64;
    use crate::util::prng::Rng;

    #[test]
    fn realify_handles_conjugate_pairs() {
        // Eigen-decomposition of a 2x2 rotation-like matrix: one conj pair.
        let th = 0.9f64;
        let m = Mat::from_rows(&[&[th.cos(), -th.sin()], &[th.sin(), th.cos()]]);
        let e = eig(&ZMat::from_real(&m)).unwrap();
        let p = realify_smallest(&e, 2);
        assert_eq!(p.ncols, 2);
        // Columns must be linearly independent (span R^2 here).
        let (q, r) = p.qr_thin();
        assert!(q.ncols == 2 && r[(1, 1)].abs() > 1e-8);
    }

    #[test]
    fn realify_orders_by_magnitude() {
        let mut z = ZMat::zeros(3, 3);
        z[(0, 0)] = C64::real(10.0);
        z[(1, 1)] = C64::real(0.1);
        z[(2, 2)] = C64::real(-1.0);
        let e = eig(&z).unwrap();
        let p = realify_smallest(&e, 1);
        assert_eq!(p.ncols, 1);
        // smallest |θ| = 0.1 → its eigenvector is e2.
        assert!(p.col(0)[1].abs() > 0.99, "{:?}", p.col(0));
    }

    #[test]
    fn initial_harmonic_ritz_shapes() {
        let mut rng = Rng::new(21);
        let j = 12;
        let mut h_bar = Mat::zeros(j + 1, j);
        // Build a plausible Hessenberg: random upper + positive subdiagonal.
        for c in 0..j {
            for r in 0..=c {
                h_bar[(r, c)] = rng.normal();
            }
            h_bar[(c, c)] += 4.0; // keep well-conditioned
            h_bar[(c + 1, c)] = rng.uniform() + 0.5;
        }
        let p = harmonic_ritz_initial(&h_bar, 4).unwrap();
        assert_eq!(p.nrows, j);
        assert!(p.ncols >= 1 && p.ncols <= 4);
        // Columns normalized.
        for c in 0..p.ncols {
            assert!((crate::la::norm2(p.col(c)) - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn cycle_harmonic_ritz_reduces_to_standard_when_b_identityish() {
        // With ŴᴴV̂ = [I; 0] (the m×m identity stacked over a zero row) and
        // Ḡ = [T; 0], the problem becomes TᴴT z = θ Tᴴ z ⇔ T z = θ z.
        let t = Mat::from_rows(&[&[2.0, 1.0], &[0.0, 5.0]]);
        let mut g_bar = Mat::zeros(3, 2);
        let mut whv = Mat::zeros(3, 2);
        for c in 0..2 {
            for r in 0..2 {
                g_bar[(r, c)] = t[(r, c)];
                whv[(r, c)] = if r == c { 1.0 } else { 0.0 };
            }
        }
        let p = harmonic_ritz_cycle(&g_bar, &whv, 1).unwrap();
        // Smallest eigenvalue of T is 2 with eigenvector e1.
        assert!(p.col(0)[0].abs() > 0.99, "{:?}", p.col(0));
    }
}
