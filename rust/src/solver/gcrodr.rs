//! GCRO-DR — Generalized Conjugate Residual with inner Orthogonalization and
//! Deflated Restarting (Parks et al. 2006; the paper's Appendix B.2), the
//! engine of SKR.
//!
//! Between consecutive linear systems the solver *recycles* an approximate
//! invariant subspace `Ỹ_k` (harmonic Ritz vectors of the preconditioned
//! operator). For system i+1 it re-orthonormalizes `A⁽ⁱ⁺¹⁾Ỹ_k` into
//! `C_k` (with `A U_k = C_k`, `C_kᴴC_k = I`) and runs deflated Arnoldi with
//! the projected operator `(I − C_kC_kᴴ) A`. Right preconditioning is used
//! throughout; the recycled vectors live in the preconditioned variable
//! space (see DESIGN.md).
//!
//! The n-sized scratch (residual, operator/preconditioner outputs, the
//! correction accumulator and the Krylov basis pool) lives in a
//! [`Workspace`] shared across the solves of a sequence; per-cycle O(m)
//! arrays stay local. Pooled buffers are fully (re)initialised before any
//! read, so workspace reuse is bit-identical to fresh allocation.

use super::workspace::{
    cgs2_flops, pool_push_copy, pool_push_div, proj_flops, SolveCounters, Workspace,
};
use crate::la::{axpy, dot, norm2, Csr, Mat};
use crate::obs::{NoopObserver, SolveObserver};
use crate::precond::Preconditioner;
use crate::solver::harmonic::{harmonic_ritz_cycle, harmonic_ritz_initial};
use crate::solver::stats::{SolveStats, SolverConfig, StopReason};
use crate::util::timer::Timer;

/// Recycle state carried across the systems of a sequence.
#[derive(Default, Clone)]
pub struct Recycler {
    /// `Ỹ_k` — the subspace to recycle into the next solve (n × k columns).
    pub ytilde: Option<Vec<Vec<f64>>>,
    /// `(U, C)` pair valid for the operator identified by `fingerprint`
    /// (`A M⁻¹ U = C`, `CᴴC = I`). When the next system's operator matches,
    /// the k reseed operator-applies are skipped entirely (Parks et al.
    /// §3: re-orthonormalization is only needed when the matrix changes —
    /// the common case for families like the thermal problem, where only
    /// the right-hand side varies).
    uc: Option<(Vec<Vec<f64>>, Vec<Vec<f64>>)>,
    fingerprint: u64,
}

impl Recycler {
    pub fn new() -> Recycler {
        Recycler::default()
    }

    /// Dimension of the currently held space.
    pub fn dim(&self) -> usize {
        self.ytilde.as_ref().map_or(0, |y| y.len())
    }
}

/// Cheap order-dependent checksum of the operator (matrix values + structure
/// + preconditioner identity). Collisions are astronomically unlikely and
/// would only cost extra iterations, never a wrong answer (the final
/// residual is always checked against the true operator).
fn operator_fingerprint(a: &Csr, m_inv: &dyn Preconditioner) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01b3);
    };
    mix(a.nrows() as u64);
    mix(a.nnz() as u64);
    for &v in a.values() {
        mix(v.to_bits());
    }
    for &c in a.col_indices() {
        mix(c as u64);
    }
    // The preconditioner is a deterministic function of (A, kind), so the
    // kind tag completes the identity.
    for b in m_inv.name().bytes() {
        mix(b as u64);
    }
    h
}

/// Apply the preconditioned operator: out = A M⁻¹ v (z is scratch).
#[inline]
fn apply_op(
    a: &Csr,
    m_inv: &dyn Preconditioner,
    v: &[f64],
    z: &mut [f64],
    out: &mut [f64],
    ctr: &mut SolveCounters,
) {
    m_inv.apply(v, z);
    a.matvec_into(z, out);
    ctr.precond_applies += 1;
    ctr.matvecs += 1;
}

/// Orthonormalize the image `A·M⁻¹·Y` into C (n×k) and update U so that
/// `A M⁻¹ U = C`, `CᵀC = I`. Columns whose R-diagonal collapses are dropped
/// (rank truncation). Returns (U, C); `iters` counts the k operator applies.
#[allow(clippy::type_complexity)]
fn reseed(
    a: &Csr,
    m_inv: &dyn Preconditioner,
    y: &[Vec<f64>],
    iters: &mut usize,
    ctr: &mut SolveCounters,
) -> Option<(Vec<Vec<f64>>, Vec<Vec<f64>>)> {
    let n = a.nrows();
    let k = y.len();
    if k == 0 {
        return None;
    }
    let mut ay = Mat::zeros(n, k);
    let mut z = vec![0.0; n];
    let mut w = vec![0.0; n];
    for (j, yj) in y.iter().enumerate() {
        apply_op(a, m_inv, yj, &mut z, &mut w, ctr);
        *iters += 1;
        ay.set_col(j, &w);
    }
    let (q, r) = ay.qr_thin();
    // Detect rank collapse.
    let rmax = (0..k).map(|i| r[(i, i)].abs()).fold(0.0f64, f64::max);
    let keep: Vec<usize> = (0..k).filter(|&i| r[(i, i)].abs() > 1e-12 * rmax.max(1e-300)).collect();
    if keep.is_empty() {
        return None;
    }
    // U = Y R⁻¹ (only for kept columns — recompute a clean QR on the kept set
    // if truncation happened, for simplicity and robustness).
    if keep.len() < k {
        let ykeep: Vec<Vec<f64>> = keep.iter().map(|&i| y[i].clone()).collect();
        return reseed(a, m_inv, &ykeep, iters, ctr);
    }
    // Solve U R = Y column-wise: U[:,j] = (Y[:,0..=j] combo). Use back-substitution
    // on Rᵀ? Direct: R is k×k upper triangular, U = Y R⁻¹.
    let rinv = invert_upper(&r)?;
    let mut u_cols = vec![vec![0.0; n]; k];
    for j in 0..k {
        for (i, yi) in y.iter().enumerate().take(j + 1) {
            let c = rinv[(i, j)];
            if c != 0.0 {
                axpy(c, yi, &mut u_cols[j]);
            }
        }
    }
    let c_cols: Vec<Vec<f64>> = (0..k).map(|j| q.col(j).to_vec()).collect();
    Some((u_cols, c_cols))
}

/// Invert a small upper-triangular matrix; None if numerically singular.
fn invert_upper(r: &Mat) -> Option<Mat> {
    let k = r.ncols;
    let mut inv = Mat::zeros(k, k);
    for j in 0..k {
        let mut e = vec![0.0; k];
        e[j] = 1.0;
        let x = r.solve_upper(&e).ok()?;
        inv.set_col(j, &x);
    }
    Some(inv)
}

/// Solve A x = b with GCRO-DR, recycling through `rec`. `x` carries the
/// initial guess in and the solution out.
pub fn gcrodr(
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    m_inv: &dyn Preconditioner,
    cfg: &SolverConfig,
    rec: &mut Recycler,
) -> SolveStats {
    gcrodr_observed(a, b, x, m_inv, cfg, rec, &mut NoopObserver)
}

/// [`gcrodr`] with iteration-level observability: `obs` receives cycle
/// residuals, recycle-space installs (with their deflation dimension k and
/// whether the reseed was skipped) and harmonic-Ritz harvests. The observer
/// only ever reads copies of solver state, so iteration counts and the
/// solution are bit-identical to the unobserved path.
pub fn gcrodr_observed(
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    m_inv: &dyn Preconditioner,
    cfg: &SolverConfig,
    rec: &mut Recycler,
    obs: &mut dyn SolveObserver,
) -> SolveStats {
    gcrodr_ws(a, b, x, m_inv, cfg, rec, obs, &mut Workspace::new())
}

/// [`gcrodr_observed`] on a caller-owned [`Workspace`]. When the workspace's
/// shapes match the previous solve the n-sized scratch and the Krylov basis
/// pool are reused without reallocation.
#[allow(clippy::too_many_arguments)]
pub fn gcrodr_ws(
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    m_inv: &dyn Preconditioner,
    cfg: &SolverConfig,
    rec: &mut Recycler,
    obs: &mut dyn SolveObserver,
    ws: &mut Workspace,
) -> SolveStats {
    let timer = Timer::start();
    let n = b.len();
    let m = cfg.m.max(2);
    let k_target = cfg.k.clamp(1, m - 1);
    let bnorm = norm2(b).max(1e-300);
    let mut trace: Vec<(usize, f64)> = Vec::new();
    let mut iters = 0usize;

    ws.prepare(n, m);
    let Workspace { w, z, r, du, basis, ctr, .. } = ws;

    // r = b − A x
    r.copy_from_slice(b);
    a.matvec_into(x, w);
    ctr.matvecs += 1;
    axpy(-1.0, w, r);
    let mut rel = norm2(r) / bnorm;
    obs.on_start(n, rel);
    if cfg.record_trace {
        trace.push((0, rel));
    }
    if rel < cfg.tol {
        let stats = SolveStats {
            iters,
            seconds: timer.secs(),
            rel_residual: rel,
            stop: StopReason::Converged,
            trace,
        };
        obs.on_end(&stats);
        return stats;
    }

    // (U, C) for this system.
    let mut uc: Option<(Vec<Vec<f64>>, Vec<Vec<f64>>)> = None;
    let fp = operator_fingerprint(a, m_inv);

    // A recycle space from a different-sized system is meaningless — drop it
    // rather than panic (callers may legitimately mix problem sizes).
    if rec.ytilde.as_ref().is_some_and(|y| y.first().is_some_and(|c| c.len() != n)) {
        rec.ytilde = None;
        rec.uc = None;
    }

    if rec.fingerprint == fp && rec.uc.is_some() {
        // Operator unchanged since the previous solve: A M⁻¹ U = C still
        // holds, so skip the k reseed applies and project immediately.
        let (u, c) = rec.uc.take().unwrap();
        let k = c.len();
        du.fill(0.0);
        for j in 0..k {
            let cj = dot(&c[j], r);
            axpy(cj, &u[j], du);
            axpy(-cj, &c[j], r);
        }
        m_inv.apply(du, z);
        ctr.precond_applies += 1;
        ctr.recycle_carries += 1;
        axpy(1.0, z, x);
        obs.on_recycle(k, true);
        uc = Some((u, c));
        rel = norm2(r) / bnorm;
        rec.ytilde = None;
    } else if let Some(y) = rec.ytilde.take() {
        if let Some((u, c)) = reseed(a, m_inv, &y, &mut iters, ctr) {
            // x ← x + M⁻¹ (U Cᵀ r);   r ← r − C Cᵀ r
            let k = c.len();
            du.fill(0.0);
            for j in 0..k {
                let cj = dot(&c[j], r);
                axpy(cj, &u[j], du);
                axpy(-cj, &c[j], r);
            }
            m_inv.apply(du, z);
            ctr.precond_applies += 1;
            ctr.recycle_reseeds += 1;
            axpy(1.0, z, x);
            obs.on_recycle(k, false);
            uc = Some((u, c));
            rel = norm2(r) / bnorm;
        }
    }

    if uc.is_none() {
        // First system of the sequence: one full GMRES(m) cycle to harvest
        // harmonic Ritz vectors (Alg. 2, lines 9–18).
        let beta = norm2(r);
        let mut blen = 0usize;
        pool_push_div(basis, &mut blen, r, beta);
        let mut h_cols: Vec<Vec<f64>> = Vec::new(); // column j holds H[0..=j+1, j]
        let mut j_done = 0;
        // Incremental Givens QR of H̄ for a per-step residual estimate
        // (exactly the GMRES mechanism) — lets the cycle stop as soon as the
        // tolerance is met instead of overshooting to the restart boundary.
        let mut cs_r = vec![0.0; m];
        let mut sn_r = vec![0.0; m];
        let mut grot = vec![0.0; m + 1];
        grot[0] = beta;
        for j in 0..m {
            apply_op(a, m_inv, &basis[j], z, w, ctr);
            iters += 1;
            ctr.ortho_flops += cgs2_flops(blen, n);
            let mut coeffs = crate::la::ortho::cgs2_orthogonalize(w, &basis[..blen]);
            let hnext = crate::la::ortho::normalize(w);
            coeffs.push(hnext);
            // Rotate the new column and extend the QR.
            let mut col = coeffs.clone();
            for i in 0..j {
                let (c, s) = (cs_r[i], sn_r[i]);
                let (t0, t1) = (col[i], col[i + 1]);
                col[i] = c * t0 + s * t1;
                col[i + 1] = -s * t0 + c * t1;
            }
            let rho = col[j].hypot(col[j + 1]);
            let (c, s) = if rho == 0.0 { (1.0, 0.0) } else { (col[j] / rho, col[j + 1] / rho) };
            cs_r[j] = c;
            sn_r[j] = s;
            let (g0, g1) = (grot[j], grot[j + 1]);
            grot[j] = c * g0 + s * g1;
            grot[j + 1] = -s * g0 + c * g1;
            h_cols.push(coeffs);
            j_done = j + 1;
            let rel_est = grot[j + 1].abs() / bnorm;
            if hnext < 1e-14 * bnorm || iters >= cfg.max_iters || rel_est < cfg.tol {
                if hnext >= 1e-14 * bnorm {
                    pool_push_copy(basis, &mut blen, w);
                }
                break;
            }
            pool_push_copy(basis, &mut blen, w);
        }
        // LS solve: min ‖βe₁ − H̄ y‖ over the j_done columns.
        let mut h_bar = Mat::zeros(j_done + 1, j_done);
        for (j, col) in h_cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate().take(j_done + 1) {
                if i <= j + 1 {
                    h_bar[(i, j)] = v;
                }
            }
        }
        let mut rhs = vec![0.0; j_done + 1];
        rhs[0] = beta;
        if let Ok(y) = h_bar.lstsq(&rhs) {
            du.fill(0.0);
            for (l, yl) in y.iter().enumerate() {
                axpy(*yl, &basis[l], du);
            }
            m_inv.apply(du, z);
            ctr.precond_applies += 1;
            axpy(1.0, z, x);
            // r = V_{m+1} (βe₁ − H̄ y)
            let hy = h_bar.matvec(&y);
            let mut coef = rhs.clone();
            for i in 0..coef.len() {
                coef[i] -= hy[i];
            }
            r.fill(0.0);
            for (l, cl) in coef.iter().enumerate().take(blen) {
                axpy(*cl, &basis[l], r);
            }
            rel = norm2(r) / bnorm;
        }
        obs.on_cycle(iters, rel);
        if cfg.record_trace {
            trace.push((iters, rel));
        }
        // Harvest harmonic Ritz vectors if the cycle was long enough and the
        // Arnoldi basis is complete (no breakdown: V_{j_done+1} exists).
        // Harvest as many harmonic Ritz vectors as the cycle length allows
        // (k_target when the cycle ran long enough, fewer on early exit).
        let k_avail = k_target.min(j_done.saturating_sub(1));
        if k_avail >= 1 && blen == j_done + 1 {
            if let Ok(p) = harmonic_ritz_initial(&h_bar, k_avail) {
                let kk = p.ncols;
                // Ỹ = V_m P
                let mut y_cols = vec![vec![0.0; n]; kk];
                for j in 0..kk {
                    for l in 0..j_done {
                        axpy(p[(l, j)], &basis[l], &mut y_cols[j]);
                    }
                }
                // C = V_{m+1} Q, U = Ỹ R⁻¹ with [Q,R] = qr(H̄ P).
                let hp = h_bar.matmul(&p);
                let (q, rr) = hp.qr_thin();
                if let Some(rinv) = invert_upper(&rr) {
                    let mut u_cols = vec![vec![0.0; n]; kk];
                    let mut c_cols = vec![vec![0.0; n]; kk];
                    for j in 0..kk {
                        for (l, vl) in basis[..blen].iter().enumerate() {
                            axpy(q[(l, j)], vl, &mut c_cols[j]);
                        }
                        for i in 0..kk {
                            let c = rinv[(i, j)];
                            if c != 0.0 {
                                // y_cols and u_cols are distinct allocations:
                                // borrow directly, no per-column clone.
                                axpy(c, &y_cols[i], &mut u_cols[j]);
                            }
                        }
                    }
                    ctr.harvests += 1;
                    obs.on_harvest(kk);
                    uc = Some((u_cols, c_cols));
                }
            }
        }
    }

    // Deflated restarting cycles (Alg. 2, lines 19–33).
    while rel >= cfg.tol && iters < cfg.max_iters {
        let Some((u, c)) = uc.as_ref() else {
            // No recycle space (degenerate first cycle): fall back to GMRES.
            // The fallback runs on its own workspace, so its fine-grained op
            // counts are not tallied into `ctr` — a deterministic (and rare)
            // undercount, which is all the regression gate needs.
            let mut sub = cfg.clone();
            sub.max_iters = cfg.max_iters - iters;
            let stats = crate::solver::gmres::gmres(a, b, x, m_inv, &sub);
            let stats = SolveStats {
                iters: iters + stats.iters,
                seconds: timer.secs(),
                rel_residual: stats.rel_residual,
                stop: stats.stop,
                trace,
            };
            obs.on_end(&stats);
            return stats;
        };
        let k = c.len();
        let s = m - k; // inner Arnoldi steps this cycle

        // D from unit-norm scaling of U's columns: Û = U D, A Û = C D.
        let dvals: Vec<f64> = u.iter().map(|uj| {
            let nrm = norm2(uj);
            if nrm > 1e-300 { 1.0 / nrm } else { 1.0 }
        }).collect();

        // Arnoldi on (I − CCᵀ) A_op.
        let rn = norm2(r);
        let mut blen = 0usize;
        {
            // v₁ = r/‖r‖, re-orthogonalized against C for numerical safety.
            pool_push_div(basis, &mut blen, r, rn);
            ctr.ortho_flops += proj_flops(k, n);
            let v1 = &mut basis[0];
            for cj in c {
                let h = dot(cj, v1);
                axpy(-h, cj, v1);
            }
            crate::la::ortho::normalize(v1);
        }
        let mut bmat = Mat::zeros(k, s); // B = Cᵀ A V_s
        let mut h_cols: Vec<Vec<f64>> = Vec::new();
        let mut s_done = 0;
        // Per-step residual estimate via incremental Givens QR of the
        // Hessenberg block of Ḡ. Because Ŵ = [C V] has orthonormal columns
        // and r ∈ range(Ŵ) at cycle start, the least-squares residual after
        // j steps is |grot[j+1]| — the arrowhead rows (D, B) are absorbed
        // exactly by the triangular solve and contribute nothing.
        let mut cs_r = vec![0.0; s];
        let mut sn_r = vec![0.0; s];
        let mut grot = vec![0.0; s + 1];
        grot[0] = dot(&basis[0], r);
        for j in 0..s {
            apply_op(a, m_inv, &basis[j], z, w, ctr);
            iters += 1;
            // Project out C, recording B.
            ctr.ortho_flops += proj_flops(k, n);
            for (i, ci) in c.iter().enumerate() {
                let h = dot(ci, w);
                bmat[(i, j)] = h;
                axpy(-h, ci, w);
            }
            ctr.ortho_flops += cgs2_flops(blen, n);
            let mut coeffs = crate::la::ortho::cgs2_orthogonalize(w, &basis[..blen]);
            let hnext = crate::la::ortho::normalize(w);
            coeffs.push(hnext);
            // Extend the Givens QR with the rotated Hessenberg column.
            let mut col = coeffs.clone();
            for i in 0..j {
                let (cg, sg) = (cs_r[i], sn_r[i]);
                let (t0, t1) = (col[i], col[i + 1]);
                col[i] = cg * t0 + sg * t1;
                col[i + 1] = -sg * t0 + cg * t1;
            }
            let rho = col[j].hypot(col[j + 1]);
            let (cg, sg) = if rho == 0.0 { (1.0, 0.0) } else { (col[j] / rho, col[j + 1] / rho) };
            cs_r[j] = cg;
            sn_r[j] = sg;
            let (g0, g1) = (grot[j], grot[j + 1]);
            grot[j] = cg * g0 + sg * g1;
            grot[j + 1] = -sg * g0 + cg * g1;
            h_cols.push(coeffs);
            s_done = j + 1;
            let rel_est = grot[j + 1].abs() / bnorm;
            if hnext < 1e-14 * bnorm || iters >= cfg.max_iters || rel_est < cfg.tol {
                if hnext >= 1e-14 * bnorm {
                    pool_push_copy(basis, &mut blen, w);
                }
                break;
            }
            pool_push_copy(basis, &mut blen, w);
        }
        if s_done == 0 {
            break;
        }
        let mdim = k + s_done;

        // Ḡ = [D B; 0 H̄]  ((mdim+1) × mdim).
        let mut g_bar = Mat::zeros(mdim + 1, mdim);
        for (i, &d) in dvals.iter().enumerate() {
            g_bar[(i, i)] = d;
        }
        for j in 0..s_done {
            for i in 0..k {
                g_bar[(i, k + j)] = bmat[(i, j)];
            }
            for (i, &v) in h_cols[j].iter().enumerate() {
                g_bar[(k + i, k + j)] = v;
            }
        }

        // Ŵᵀ r (W = [C V_{s+1}]).
        let mut rhs = vec![0.0; mdim + 1];
        for (i, ci) in c.iter().enumerate() {
            rhs[i] = dot(ci, r);
        }
        for (l, vl) in basis[..blen].iter().enumerate() {
            rhs[k + l] = dot(vl, r);
        }

        let Ok(y) = g_bar.lstsq(&rhs) else { break };

        // x ← x + M⁻¹ (V̂ y) with V̂ = [Û V_s].
        du.fill(0.0);
        for j in 0..k {
            let coef = y[j] * dvals[j];
            if coef != 0.0 {
                axpy(coef, &u[j], du);
            }
        }
        for j in 0..s_done {
            axpy(y[k + j], &basis[j], du);
        }
        m_inv.apply(du, z);
        ctr.precond_applies += 1;
        axpy(1.0, z, x);

        // r ← r − Ŵ (Ḡ y).
        let gy = g_bar.matvec(&y);
        for (i, ci) in c.iter().enumerate() {
            axpy(-gy[i], ci, r);
        }
        for (l, vl) in basis[..blen].iter().enumerate() {
            axpy(-gy[k + l], vl, r);
        }
        rel = norm2(r) / bnorm;
        obs.on_cycle(iters, rel);
        if cfg.record_trace {
            trace.push((iters, rel));
        }

        // Update the recycle space from this cycle's harmonic Ritz problem.
        // ŴᵀV̂: Ĉᵀ blocks computed from available quantities.
        let mut whv = Mat::zeros(mdim + 1, mdim);
        // CᵀÛ (k×k) and V_{s+1}ᵀÛ ((s_done+1)×k).
        for j in 0..k {
            let uhat: Vec<f64> = u[j].iter().map(|v| v * dvals[j]).collect();
            for (i, ci) in c.iter().enumerate() {
                whv[(i, j)] = dot(ci, &uhat);
            }
            for (l, vl) in basis[..blen].iter().enumerate() {
                whv[(k + l, j)] = dot(vl, &uhat);
            }
        }
        // CᵀV_s = 0 (V ⊥ C), V_{s+1}ᵀV_s = [I; 0].
        for j in 0..s_done {
            whv[(k + j, k + j)] = 1.0;
        }
        if let Ok(p) = harmonic_ritz_cycle(&g_bar, &whv, k_target) {
            let kk = p.ncols;
            if kk >= 1 {
                // Ỹ = V̂ P.
                let mut y_cols = vec![vec![0.0; n]; kk];
                for j in 0..kk {
                    for i in 0..k {
                        let coef = p[(i, j)] * dvals[i];
                        if coef != 0.0 {
                            axpy(coef, &u[i], &mut y_cols[j]);
                        }
                    }
                    for l in 0..s_done {
                        axpy(p[(k + l, j)], &basis[l], &mut y_cols[j]);
                    }
                }
                // [Q,R] = qr(Ḡ P); C' = Ŵ Q; U' = Ỹ R⁻¹.
                let gp = g_bar.matmul(&p);
                let (q, rr) = gp.qr_thin();
                if let Some(rinv) = invert_upper(&rr) {
                    let mut c_new = vec![vec![0.0; n]; kk];
                    let mut u_new = vec![vec![0.0; n]; kk];
                    for j in 0..kk {
                        for (i, ci) in c.iter().enumerate() {
                            axpy(q[(i, j)], ci, &mut c_new[j]);
                        }
                        for (l, vl) in basis[..blen].iter().enumerate() {
                            axpy(q[(k + l, j)], vl, &mut c_new[j]);
                        }
                        for i in 0..kk {
                            let coef = rinv[(i, j)];
                            if coef != 0.0 {
                                axpy(coef, &y_cols[i], &mut u_new[j]);
                            }
                        }
                    }
                    ctr.harvests += 1;
                    obs.on_harvest(kk);
                    uc = Some((u_new, c_new));
                }
            }
        }
    }

    // Keep Ỹ = U for the next system (Alg. 2, line 34), plus the exact
    // (U, C) pair so a next solve with the *same* operator can skip reseed.
    if let Some((u, c)) = uc {
        let mut y: Vec<Vec<f64>> = u.clone();
        for col in &mut y {
            crate::la::ortho::normalize(col);
        }
        rec.ytilde = Some(y);
        rec.uc = Some((u, c));
        rec.fingerprint = fp;
    }

    // Honest final residual; r's recurrence value is dead, so the pooled
    // buffer is reused for the true residual.
    r.copy_from_slice(b);
    a.matvec_into(x, w);
    ctr.matvecs += 1;
    axpy(-1.0, w, r);
    let final_rel = norm2(r) / bnorm;
    let stop = if final_rel < cfg.tol * 1.5 {
        StopReason::Converged
    } else if iters >= cfg.max_iters {
        StopReason::MaxIters
    } else {
        StopReason::Breakdown
    };
    let stats = SolveStats { iters, seconds: timer.secs(), rel_residual: final_rel, stop, trace };
    obs.on_end(&stats);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};
    use crate::precond::{Identity, PrecondKind};
    use crate::util::prng::Rng;

    #[test]
    fn single_system_matches_gmres_solution() {
        let a = nonsym(150);
        let mut rng = Rng::new(31);
        let xtrue = rng.normals(150);
        let b = a.matvec(&xtrue);
        let cfg = SolverConfig::default().with_tol(1e-10);
        let mut x = vec![0.0; 150];
        let mut rec = Recycler::new();
        let stats = gcrodr(&a, &b, &mut x, &Identity, &cfg, &mut rec);
        assert!(stats.converged(), "{stats:?}");
        for (u, v) in x.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-6, "{u} vs {v}");
        }
        assert!(rec.dim() > 0, "recycle space should be harvested");
    }

    #[test]
    fn recycling_speeds_up_similar_sequence() {
        // A sequence of slightly perturbed SPD systems: GCRO-DR with warm
        // recycle must use clearly fewer total iterations than solving each
        // from scratch (k=0 ⇒ GMRES-equivalent baseline).
        let n = 300;
        let base = lap1d(n);
        let cfg = SolverConfig::default().with_tol(1e-8).with_m(30).with_k(8);
        let mut rng = Rng::new(17);

        let systems: Vec<(Csr, Vec<f64>)> = (0..6)
            .map(|i| {
                let eps = 0.01 * (i as f64);
                let a = base.add_diag(eps);
                let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (a, b)
            })
            .collect();

        let mut rec = Recycler::new();
        let mut recycled_iters = 0;
        for (a, b) in &systems {
            let mut x = vec![0.0; n];
            let s = gcrodr(a, b, &mut x, &Identity, &cfg, &mut rec);
            assert!(s.converged(), "{s:?}");
            recycled_iters += s.iters;
        }

        let mut fresh_iters = 0;
        for (a, b) in &systems {
            let mut x = vec![0.0; n];
            let s = crate::solver::gmres::gmres(a, b, &mut x, &Identity, &cfg);
            assert!(s.converged());
            fresh_iters += s.iters;
        }
        assert!(
            (recycled_iters as f64) < 0.8 * fresh_iters as f64,
            "recycled {recycled_iters} vs fresh {fresh_iters}"
        );
    }

    #[test]
    fn converges_with_all_preconditioners() {
        let a = nonsym(120);
        let mut rng = Rng::new(3);
        let xtrue = rng.normals(120);
        let b = a.matvec(&xtrue);
        for kind in PrecondKind::ALL {
            let p = kind.build(&a).unwrap();
            let mut x = vec![0.0; 120];
            let mut rec = Recycler::new();
            let cfg = SolverConfig::default().with_tol(1e-9).with_m(25).with_k(6);
            let s = gcrodr(&a, &b, &mut x, p.as_ref(), &cfg, &mut rec);
            assert!(s.converged(), "{kind:?}: {s:?}");
            assert!(s.rel_residual < 1e-8, "{kind:?}: {}", s.rel_residual);
        }
    }

    #[test]
    fn observer_has_zero_impact_on_recycled_sequence() {
        // Solve the same 3-system sequence twice — once silently, once with a
        // recording observer — and require bit-identical iteration counts and
        // solutions, plus recycle events on the warm solves.
        use crate::obs::{RecordingObserver, SolveEvent};
        let n = 200;
        let base = lap1d(n);
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(25).with_k(6);
        let mut rng = Rng::new(91);
        let systems: Vec<(Csr, Vec<f64>)> =
            (0..3).map(|i| (base.add_diag(0.01 * i as f64), rng.normals(n))).collect();

        let mut rec1 = Recycler::new();
        let mut plain: Vec<(Vec<f64>, SolveStats)> = Vec::new();
        for (a, b) in &systems {
            let mut x = vec![0.0; n];
            let s = gcrodr(a, b, &mut x, &Identity, &cfg, &mut rec1);
            plain.push((x, s));
        }

        let mut rec2 = Recycler::new();
        for (i, (a, b)) in systems.iter().enumerate() {
            let mut x = vec![0.0; n];
            let mut obs = RecordingObserver::new();
            let s = gcrodr_observed(a, b, &mut x, &Identity, &cfg, &mut rec2, &mut obs);
            assert_eq!(s.iters, plain[i].1.iters, "system {i}");
            assert_eq!(s.stop, plain[i].1.stop, "system {i}");
            for (u, v) in x.iter().zip(&plain[i].0) {
                assert_eq!(u.to_bits(), v.to_bits(), "system {i}");
            }
            assert!(matches!(obs.events.first(), Some(SolveEvent::Start { .. })));
            assert!(matches!(obs.events.last(), Some(SolveEvent::End { .. })));
            if i > 0 {
                // Warm solves must report the installed recycle space.
                assert!(
                    obs.events.iter().any(|e| matches!(e, SolveEvent::Recycle { k, .. } if *k > 0)),
                    "system {i} recorded no recycle event: {:?}",
                    obs.events
                );
                assert!(obs.max_deflation_dim() >= 1);
            }
        }
    }

    #[test]
    fn workspace_reuse_is_bit_identical() {
        // Acceptance gate: a shared workspace threaded through the sequence
        // must reproduce the fresh-workspace solves bit-for-bit.
        let n = 200;
        let base = lap1d(n);
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(25).with_k(6);
        let mut rng = Rng::new(91);
        let systems: Vec<(Csr, Vec<f64>)> =
            (0..3).map(|i| (base.add_diag(0.01 * i as f64), rng.normals(n))).collect();

        let mut rec1 = Recycler::new();
        let mut plain: Vec<(Vec<f64>, SolveStats)> = Vec::new();
        for (a, b) in &systems {
            let mut x = vec![0.0; n];
            let s = gcrodr(a, b, &mut x, &Identity, &cfg, &mut rec1);
            plain.push((x, s));
        }

        let mut rec2 = Recycler::new();
        let mut ws = Workspace::new();
        for (i, (a, b)) in systems.iter().enumerate() {
            let mut x = vec![0.0; n];
            let s = gcrodr_ws(a, b, &mut x, &Identity, &cfg, &mut rec2, &mut NoopObserver, &mut ws);
            assert_eq!(s.iters, plain[i].1.iters, "system {i}");
            assert_eq!(s.rel_residual.to_bits(), plain[i].1.rel_residual.to_bits(), "system {i}");
            for (u, v) in x.iter().zip(&plain[i].0) {
                assert_eq!(u.to_bits(), v.to_bits(), "system {i}");
            }
        }
        assert_eq!(ws.reuse_count(), 2);
    }

    #[test]
    fn zero_rhs_is_trivial() {
        let a = lap1d(20);
        let mut x = vec![0.0; 20];
        let mut rec = Recycler::new();
        let s = gcrodr(&a, &[0.0; 20], &mut x, &Identity, &SolverConfig::default(), &mut rec);
        assert_eq!(s.iters, 0);
        assert!(s.converged());
    }

    #[test]
    fn respects_max_iters() {
        let a = lap1d(400);
        let b = vec![1.0; 400];
        let mut x = vec![0.0; 400];
        let mut rec = Recycler::new();
        let cfg = SolverConfig::default().with_tol(1e-14).with_max_iters(20).with_m(10).with_k(3);
        let s = gcrodr(&a, &b, &mut x, &Identity, &cfg, &mut rec);
        assert!(s.iters <= 25, "{}", s.iters);
    }

    #[test]
    fn counters_track_recycle_events() {
        // Same operator solved twice on one workspace: the first solve
        // harvests (no install), the second installs via the cheap carry
        // path; counters must be bit-stable across identical reruns.
        let n = 200;
        let a = lap1d(n);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.07).cos()).collect();
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(25).with_k(6);
        let run = || {
            let mut rec = Recycler::new();
            let mut ws = Workspace::new();
            for _ in 0..2 {
                let mut x = vec![0.0; n];
                let s =
                    gcrodr_ws(&a, &b, &mut x, &Identity, &cfg, &mut rec, &mut NoopObserver, &mut ws);
                assert!(s.converged(), "{s:?}");
            }
            *ws.counters()
        };
        let c1 = run();
        let c2 = run();
        assert_eq!(c1, c2, "counters must be bit-stable across identical reruns");
        assert!(c1.harvests >= 1, "{c1:?}");
        assert_eq!(c1.recycle_carries, 1, "{c1:?}");
        assert_eq!(c1.recycle_reseeds, 0, "{c1:?}");
        assert!(c1.matvecs > 0 && c1.precond_applies > 0 && c1.ortho_flops > 0);

        // A perturbed operator on the third solve must take the reseed path.
        let mut rec = Recycler::new();
        let mut ws = Workspace::new();
        let mut x = vec![0.0; n];
        gcrodr_ws(&a, &b, &mut x, &Identity, &cfg, &mut rec, &mut NoopObserver, &mut ws);
        let a2 = a.add_diag(0.01);
        let mut x2 = vec![0.0; n];
        gcrodr_ws(&a2, &b, &mut x2, &Identity, &cfg, &mut rec, &mut NoopObserver, &mut ws);
        assert_eq!(ws.counters().recycle_reseeds, 1, "{:?}", ws.counters());
        assert_eq!(ws.counters().recycle_carries, 0, "{:?}", ws.counters());
    }

    #[test]
    fn recycle_space_carries_across_matching_dims() {
        let a = nonsym(100);
        let b = vec![1.0; 100];
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(20).with_k(5);
        let mut rec = Recycler::new();
        let mut x = vec![0.0; 100];
        gcrodr(&a, &b, &mut x, &Identity, &cfg, &mut rec);
        let k1 = rec.dim();
        assert!(k1 >= 1 && k1 <= 5);
        // Second solve must succeed from the warm space.
        let mut x2 = vec![0.0; 100];
        let s2 = gcrodr(&a, &b, &mut x2, &Identity, &cfg, &mut rec);
        assert!(s2.converged());
        // Identical system solved twice: the warm solve's Krylov work must not
        // exceed the cold solve's by more than the k reseed operator applies
        // (which `iters` counts honestly).
        let mut rec_fresh = Recycler::new();
        let mut x3 = vec![0.0; 100];
        let s3 = gcrodr(&a, &b, &mut x3, &Identity, &cfg, &mut rec_fresh);
        assert!(
            s2.iters <= s3.iters + cfg.k,
            "warm {} vs cold {} (+k={})",
            s2.iters,
            s3.iters,
            cfg.k
        );
    }
}
