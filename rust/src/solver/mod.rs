//! Krylov solvers: the GMRES(m) baseline and the paper's GCRO-DR recycling
//! engine, plus sequence-level drivers used by the coordinator and benches.
//!
//! The drivers own the per-sequence reusable state: one [`Workspace`] (Krylov
//! basis, Hessenberg, Givens and scratch vectors), one cached
//! `SymbolicPrecond` keyed on the matrix [`Sparsity`], and one [`Recycler`].
//! [`solve_sequence_traced`] reports how often each was reused via
//! [`SequenceReuse`].

pub mod gcrodr;
pub mod gmres;
pub mod harmonic;
pub mod stats;
pub mod workspace;

pub use gcrodr::{gcrodr, gcrodr_observed, gcrodr_ws, Recycler};
pub use gmres::{gmres, gmres_observed, gmres_ws};
pub use stats::{SolveStats, SolverConfig, StopReason};
pub use workspace::{SolveCounters, Workspace};

use crate::la::{Csr, Sparsity};
use crate::obs::NoopObserver;
use crate::precond::{PrecondKind, SymbolicPrecond};
use anyhow::Result;
use std::sync::Arc;

/// Which engine solves the sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Independent restarted GMRES per system (the paper's baseline).
    Gmres,
    /// GCRO-DR with Krylov-subspace recycling across systems (SKR's solver).
    SkrRecycle,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "gmres" => Engine::Gmres,
            "skr" | "gcrodr" | "recycle" => Engine::SkrRecycle,
            other => anyhow::bail!("unknown engine {other:?}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Engine::Gmres => "GMRES",
            Engine::SkrRecycle => "SKR",
        }
    }
}

/// A linear system A x = b tagged with its generating parameters (the sort
/// key) and an id tracing it back to its position in the original stream.
#[derive(Debug, Clone)]
pub struct LinearSystem {
    pub id: usize,
    pub a: Csr,
    pub b: Vec<f64>,
    /// Flattened parameter matrix P⁽ⁱ⁾ used by the sorting algorithm.
    pub params: Vec<f64>,
}

/// Tallies of the structure/scratch reuse a sequence driver achieved.
/// `sparsity_reuse` counts systems whose matrix shared the previous system's
/// `Arc<Sparsity>` by pointer; `symbolic_reuse` counts systems whose
/// preconditioner skipped the symbolic phase; `workspace_reuse` counts solves
/// that reran on the pooled Krylov buffers without reallocation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SequenceReuse {
    pub systems: usize,
    pub sparsity_reuse: usize,
    pub symbolic_reuse: usize,
    pub workspace_reuse: usize,
    /// Deterministic op counters summed over every solve of the sequence.
    pub counters: SolveCounters,
}

/// Solve a sequence of systems **in the given order** with one engine and a
/// per-system preconditioner. Returns per-system solutions and stats.
pub fn solve_sequence(
    systems: &[LinearSystem],
    engine: Engine,
    precond: PrecondKind,
    cfg: &SolverConfig,
) -> Result<Vec<(Vec<f64>, SolveStats)>> {
    Ok(solve_sequence_traced(systems, engine, precond, cfg)?.0)
}

/// [`solve_sequence`] plus the [`SequenceReuse`] tallies. The reuse caches
/// change no arithmetic: a cached symbolic phase runs the same numeric
/// refactor a fresh build would, and pooled solver buffers are fully
/// reinitialised per solve, so results are bit-identical to per-system fresh
/// solves.
pub fn solve_sequence_traced(
    systems: &[LinearSystem],
    engine: Engine,
    precond: PrecondKind,
    cfg: &SolverConfig,
) -> Result<(Vec<(Vec<f64>, SolveStats)>, SequenceReuse)> {
    let mut out = Vec::with_capacity(systems.len());
    let mut rec = Recycler::new();
    let mut ws = Workspace::new();
    let mut symbolic: Option<SymbolicPrecond> = None;
    let mut prev_sparsity: Option<Arc<Sparsity>> = None;
    let mut reuse = SequenceReuse { systems: systems.len(), ..Default::default() };
    for sys in systems {
        if prev_sparsity.as_ref().is_some_and(|sp| Arc::ptr_eq(sp, sys.a.sparsity())) {
            reuse.sparsity_reuse += 1;
        } else {
            prev_sparsity = Some(sys.a.sparsity().clone());
        }
        let sym = match symbolic.take() {
            Some(s) if s.matches(&sys.a) => {
                reuse.symbolic_reuse += 1;
                s
            }
            _ => precond.symbolic(sys.a.sparsity())?,
        };
        let p = sym.refactor(&sys.a)?;
        symbolic = Some(sym);
        let mut x = vec![0.0; sys.b.len()];
        let stats = match engine {
            Engine::Gmres => {
                gmres_ws(&sys.a, &sys.b, &mut x, p.as_ref(), cfg, &mut NoopObserver, &mut ws)
            }
            Engine::SkrRecycle => gcrodr_ws(
                &sys.a,
                &sys.b,
                &mut x,
                p.as_ref(),
                cfg,
                &mut rec,
                &mut NoopObserver,
                &mut ws,
            ),
        };
        out.push((x, stats));
    }
    reuse.workspace_reuse = ws.reuse_count();
    reuse.counters = *ws.counters();
    Ok((out, reuse))
}

/// Streaming variant of [`solve_sequence_traced`]: systems are produced on
/// demand by `fetch` (bounded memory — one system lives at a time) and each
/// `(system, solution, stats)` triple is handed to `emit` as soon as it is
/// solved. The per-sequence reusable state (one [`Workspace`], one cached
/// `SymbolicPrecond`, one [`Recycler`]) is threaded through the solves in
/// exactly the order [`solve_sequence_traced`] would, so for the same
/// systems the solutions, stats and [`SolveCounters`] are bit-identical.
/// This is the shard-solve path of `skr work`.
pub fn solve_stream<F, G>(
    ids: &[usize],
    mut fetch: F,
    engine: Engine,
    precond: PrecondKind,
    cfg: &SolverConfig,
    mut emit: G,
) -> Result<SequenceReuse>
where
    F: FnMut(usize) -> Result<LinearSystem>,
    G: FnMut(LinearSystem, Vec<f64>, SolveStats) -> Result<()>,
{
    let mut rec = Recycler::new();
    let mut ws = Workspace::new();
    let mut symbolic: Option<SymbolicPrecond> = None;
    let mut prev_sparsity: Option<Arc<Sparsity>> = None;
    let mut reuse = SequenceReuse { systems: ids.len(), ..Default::default() };
    for &id in ids {
        let sys = fetch(id)?;
        if prev_sparsity.as_ref().is_some_and(|sp| Arc::ptr_eq(sp, sys.a.sparsity())) {
            reuse.sparsity_reuse += 1;
        } else {
            prev_sparsity = Some(sys.a.sparsity().clone());
        }
        let sym = match symbolic.take() {
            Some(s) if s.matches(&sys.a) => {
                reuse.symbolic_reuse += 1;
                s
            }
            _ => precond.symbolic(sys.a.sparsity())?,
        };
        let p = sym.refactor(&sys.a)?;
        symbolic = Some(sym);
        let mut x = vec![0.0; sys.b.len()];
        let stats = match engine {
            Engine::Gmres => {
                gmres_ws(&sys.a, &sys.b, &mut x, p.as_ref(), cfg, &mut NoopObserver, &mut ws)
            }
            Engine::SkrRecycle => gcrodr_ws(
                &sys.a,
                &sys.b,
                &mut x,
                p.as_ref(),
                cfg,
                &mut rec,
                &mut NoopObserver,
                &mut ws,
            ),
        };
        emit(sys, x, stats)?;
    }
    reuse.workspace_reuse = ws.reuse_count();
    reuse.counters = *ws.counters();
    Ok(reuse)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::nonsym;
    use crate::util::prng::Rng;

    fn sequence(n: usize, count: usize) -> Vec<LinearSystem> {
        let base = nonsym(n);
        let mut rng = Rng::new(42);
        (0..count)
            .map(|i| {
                let a = base.add_diag(0.02 * i as f64);
                let b = rng.normals(n);
                LinearSystem { id: i, a, b, params: vec![i as f64] }
            })
            .collect()
    }

    #[test]
    fn both_engines_solve_the_same_sequence() {
        let systems = sequence(120, 4);
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(20).with_k(5);
        for engine in [Engine::Gmres, Engine::SkrRecycle] {
            let res = solve_sequence(&systems, engine, PrecondKind::Jacobi, &cfg).unwrap();
            assert_eq!(res.len(), 4);
            for (i, (x, s)) in res.iter().enumerate() {
                assert!(s.converged(), "{engine:?} sys {i}: {s:?}");
                // Check the actual residual independently.
                let ax = systems[i].a.matvec(x);
                let r: f64 = systems[i]
                    .b
                    .iter()
                    .zip(&ax)
                    .map(|(bi, ai)| (bi - ai) * (bi - ai))
                    .sum::<f64>()
                    .sqrt();
                let bn = crate::la::norm2(&systems[i].b);
                assert!(r / bn < 1e-8, "{engine:?} sys {i} resid {}", r / bn);
            }
        }
    }

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("gmres").unwrap(), Engine::Gmres);
        assert_eq!(Engine::parse("SKR").unwrap(), Engine::SkrRecycle);
        assert!(Engine::parse("magic").is_err());
    }

    #[test]
    fn sequence_reuses_symbolic_and_workspace() {
        // add_diag rebuilds the pattern (no Arc sharing), but the patterns
        // are equal, so the symbolic cache and the solver workspace are
        // reused for every system after the first.
        let systems = sequence(120, 4);
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(20).with_k(5);
        for engine in [Engine::Gmres, Engine::SkrRecycle] {
            let (res, reuse) =
                solve_sequence_traced(&systems, engine, PrecondKind::Ilu, &cfg).unwrap();
            assert_eq!(res.len(), 4);
            assert_eq!(reuse.systems, 4, "{engine:?}");
            assert_eq!(reuse.sparsity_reuse, 0, "{engine:?}");
            assert_eq!(reuse.symbolic_reuse, 3, "{engine:?}");
            assert_eq!(reuse.workspace_reuse, 3, "{engine:?}");
        }
    }

    #[test]
    fn sequence_counts_shared_sparsity() {
        // Systems stamped onto one shared Arc<Sparsity> (the pde fast path)
        // are recognised by pointer, not pattern comparison.
        let base = nonsym(80);
        let sp = base.sparsity().clone();
        let mut rng = Rng::new(7);
        let systems: Vec<LinearSystem> = (0..3)
            .map(|i| {
                let mut vals = base.values().to_vec();
                for v in &mut vals {
                    *v *= 1.0 + 0.01 * i as f64;
                }
                let a = Csr::with_values(sp.clone(), vals).unwrap();
                LinearSystem { id: i, a, b: rng.normals(80), params: vec![i as f64] }
            })
            .collect();
        let cfg = SolverConfig::default().with_tol(1e-9);
        let (_, reuse) =
            solve_sequence_traced(&systems, Engine::Gmres, PrecondKind::Jacobi, &cfg).unwrap();
        assert_eq!(reuse.sparsity_reuse, 2);
        assert_eq!(reuse.symbolic_reuse, 2);
        assert_eq!(reuse.workspace_reuse, 2);
    }

    #[test]
    fn stream_matches_sequence_bitwise() {
        // The dist worker's contract: fetching systems on demand through
        // solve_stream yields the same bits (solutions, stats, reuse and
        // op-counter tallies) as the in-memory sequence driver.
        let systems = sequence(100, 3);
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(20).with_k(4);
        for engine in [Engine::Gmres, Engine::SkrRecycle] {
            let (seq, seq_reuse) =
                solve_sequence_traced(&systems, engine, PrecondKind::Jacobi, &cfg).unwrap();
            let ids: Vec<usize> = (0..systems.len()).collect();
            let mut streamed: Vec<(Vec<f64>, SolveStats)> = Vec::new();
            let reuse = solve_stream(
                &ids,
                |id| Ok(systems[id].clone()),
                engine,
                PrecondKind::Jacobi,
                &cfg,
                |_sys, x, s| {
                    streamed.push((x, s));
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(reuse, seq_reuse, "{engine:?}");
            assert_eq!(seq.len(), streamed.len());
            for ((x1, s1), (x2, s2)) in seq.iter().zip(&streamed) {
                assert_eq!(s1.iters, s2.iters);
                assert_eq!(s1.stop, s2.stop);
                assert_eq!(s1.rel_residual.to_bits(), s2.rel_residual.to_bits());
                for (u, v) in x1.iter().zip(x2) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
        }
    }

    #[test]
    fn traced_matches_untraced_bitwise() {
        let systems = sequence(100, 3);
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(20).with_k(4);
        for engine in [Engine::Gmres, Engine::SkrRecycle] {
            let plain = solve_sequence(&systems, engine, PrecondKind::Jacobi, &cfg).unwrap();
            let (traced, _) =
                solve_sequence_traced(&systems, engine, PrecondKind::Jacobi, &cfg).unwrap();
            for ((x1, s1), (x2, s2)) in plain.iter().zip(&traced) {
                assert_eq!(s1.iters, s2.iters);
                assert_eq!(s1.rel_residual.to_bits(), s2.rel_residual.to_bits());
                for (u, v) in x1.iter().zip(x2) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
        }
    }
}
