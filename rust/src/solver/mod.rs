//! Krylov solvers: the GMRES(m) baseline and the paper's GCRO-DR recycling
//! engine, plus sequence-level drivers used by the coordinator and benches.

pub mod gcrodr;
pub mod gmres;
pub mod harmonic;
pub mod stats;

pub use gcrodr::{gcrodr, gcrodr_observed, Recycler};
pub use gmres::{gmres, gmres_observed};
pub use stats::{SolveStats, SolverConfig, StopReason};

use crate::la::Csr;
use crate::precond::PrecondKind;
use anyhow::Result;

/// Which engine solves the sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Independent restarted GMRES per system (the paper's baseline).
    Gmres,
    /// GCRO-DR with Krylov-subspace recycling across systems (SKR's solver).
    SkrRecycle,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "gmres" => Engine::Gmres,
            "skr" | "gcrodr" | "recycle" => Engine::SkrRecycle,
            other => anyhow::bail!("unknown engine {other:?}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Engine::Gmres => "GMRES",
            Engine::SkrRecycle => "SKR",
        }
    }
}

/// A linear system A x = b tagged with its generating parameters (the sort
/// key) and an id tracing it back to its position in the original stream.
#[derive(Debug, Clone)]
pub struct LinearSystem {
    pub id: usize,
    pub a: Csr,
    pub b: Vec<f64>,
    /// Flattened parameter matrix P⁽ⁱ⁾ used by the sorting algorithm.
    pub params: Vec<f64>,
}

/// Solve a sequence of systems **in the given order** with one engine and a
/// per-system preconditioner. Returns per-system solutions and stats.
pub fn solve_sequence(
    systems: &[LinearSystem],
    engine: Engine,
    precond: PrecondKind,
    cfg: &SolverConfig,
) -> Result<Vec<(Vec<f64>, SolveStats)>> {
    let mut out = Vec::with_capacity(systems.len());
    let mut rec = Recycler::new();
    for sys in systems {
        let p = precond.build(&sys.a)?;
        let mut x = vec![0.0; sys.b.len()];
        let stats = match engine {
            Engine::Gmres => gmres(&sys.a, &sys.b, &mut x, p.as_ref(), cfg),
            Engine::SkrRecycle => gcrodr(&sys.a, &sys.b, &mut x, p.as_ref(), cfg, &mut rec),
        };
        out.push((x, stats));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::nonsym;
    use crate::util::prng::Rng;

    fn sequence(n: usize, count: usize) -> Vec<LinearSystem> {
        let base = nonsym(n);
        let mut rng = Rng::new(42);
        (0..count)
            .map(|i| {
                let a = base.add_diag(0.02 * i as f64);
                let b = rng.normals(n);
                LinearSystem { id: i, a, b, params: vec![i as f64] }
            })
            .collect()
    }

    #[test]
    fn both_engines_solve_the_same_sequence() {
        let systems = sequence(120, 4);
        let cfg = SolverConfig::default().with_tol(1e-9).with_m(20).with_k(5);
        for engine in [Engine::Gmres, Engine::SkrRecycle] {
            let res = solve_sequence(&systems, engine, PrecondKind::Jacobi, &cfg).unwrap();
            assert_eq!(res.len(), 4);
            for (i, (x, s)) in res.iter().enumerate() {
                assert!(s.converged(), "{engine:?} sys {i}: {s:?}");
                // Check the actual residual independently.
                let ax = systems[i].a.matvec(x);
                let r: f64 = systems[i]
                    .b
                    .iter()
                    .zip(&ax)
                    .map(|(bi, ai)| (bi - ai) * (bi - ai))
                    .sum::<f64>()
                    .sqrt();
                let bn = crate::la::norm2(&systems[i].b);
                assert!(r / bn < 1e-8, "{engine:?} sys {i} resid {}", r / bn);
            }
        }
    }

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("gmres").unwrap(), Engine::Gmres);
        assert_eq!(Engine::parse("SKR").unwrap(), Engine::SkrRecycle);
        assert!(Engine::parse("magic").is_err());
    }
}
