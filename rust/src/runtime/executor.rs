//! Typed FNO executors over the PJRT runtime: stateful Adam training
//! (`train_step`) and inference (`predict`), with all optimizer state owned
//! by rust and threaded through the HLO signature.

use super::artifacts::Manifest;
use super::client::{literal_f32, literal_scalar, to_vec_f32, Executable, Runtime};
use anyhow::{Context, Result};
use std::path::Path;

/// A loaded FNO with training state.
pub struct FnoRuntime {
    pub manifest: Manifest,
    forward: Executable,
    train_step: Executable,
    /// Current parameters (ABI order), then Adam m and v, as literals.
    params: Vec<xla::Literal>,
    m_state: Vec<xla::Literal>,
    v_state: Vec<xla::Literal>,
    step: xla::Literal,
}

impl FnoRuntime {
    /// Load artifacts from `dir` and initialize training state.
    pub fn load(dir: &Path) -> Result<FnoRuntime> {
        let manifest = Manifest::load(dir)?;
        let rt = Runtime::cpu()?;
        let forward = rt.load_hlo_text(&dir.join(&manifest.forward_file))?;
        let train_step = rt.load_hlo_text(&dir.join(&manifest.train_step_file))?;
        let raw = manifest.load_params()?;
        let mut params = Vec::with_capacity(raw.len());
        let mut m_state = Vec::with_capacity(raw.len());
        let mut v_state = Vec::with_capacity(raw.len());
        for (data, (name, shape)) in raw.iter().zip(&manifest.params) {
            params.push(literal_f32(data, shape).with_context(|| format!("param {name}"))?);
            let zeros = vec![0.0f32; data.len()];
            m_state.push(literal_f32(&zeros, shape)?);
            v_state.push(literal_f32(&zeros, shape)?);
        }
        Ok(FnoRuntime {
            manifest,
            forward,
            train_step,
            params,
            m_state,
            v_state,
            step: literal_scalar(0.0),
        })
    }

    /// Input tensor element count per batch ([B, S, S, 1]).
    pub fn batch_elems(&self) -> usize {
        self.manifest.batch * self.manifest.grid * self.manifest.grid
    }

    /// One Adam step on a batch (x, y each `[B, S, S, 1]` flattened);
    /// returns the loss.
    pub fn train_step(&mut self, x: &[f32], y: &[f32]) -> Result<f32> {
        let (b, s) = (self.manifest.batch, self.manifest.grid);
        let x = literal_f32(x, &[b, s, s, 1])?;
        let y = literal_f32(y, &[b, s, s, 1])?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * self.params.len() + 3);
        args.extend(self.params.iter());
        args.extend(self.m_state.iter());
        args.extend(self.v_state.iter());
        args.push(&self.step);
        args.push(&x);
        args.push(&y);
        let outs = self.train_step.call(&args)?;
        let n = self.params.len();
        anyhow::ensure!(outs.len() == 3 * n + 2, "train_step returned {} outputs", outs.len());
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n).collect();
        self.m_state = (&mut it).take(n).collect();
        self.v_state = (&mut it).take(n).collect();
        self.step = it.next().unwrap();
        let loss = it.next().unwrap();
        Ok(loss.get_first_element::<f32>()?)
    }

    /// Forward pass on a batch; returns the flattened prediction.
    pub fn predict(&self, x: &[f32]) -> Result<Vec<f32>> {
        let (b, s) = (self.manifest.batch, self.manifest.grid);
        let x = literal_f32(x, &[b, s, s, 1])?;
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&x);
        let outs = self.forward.call(&args)?;
        anyhow::ensure!(outs.len() == 1, "forward returned {} outputs", outs.len());
        to_vec_f32(&outs[0])
    }

    /// Current step counter.
    pub fn steps_done(&self) -> Result<f32> {
        Ok(self.step.get_first_element::<f32>()?)
    }
}
