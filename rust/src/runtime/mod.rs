//! PJRT runtime — loads the AOT-compiled HLO artifacts (`make artifacts`)
//! and executes them on the CPU PJRT client. Python never runs here; the
//! rust binary is self-contained once `artifacts/` exists.

pub mod artifacts;
pub mod client;
pub mod executor;

pub use artifacts::Manifest;
pub use client::Runtime;
pub use executor::FnoRuntime;
