//! Artifact manifest: shapes, parameter ABI and file locations produced by
//! `python/compile/aot.py` (`make artifacts`).

use crate::util::json::Json;
use crate::util::npy;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub grid: usize,
    pub batch: usize,
    pub width: usize,
    pub modes: usize,
    pub layers: usize,
    pub lr: f64,
    /// (name, shape) in ABI order.
    pub params: Vec<(String, Vec<usize>)>,
    pub forward_file: String,
    pub train_step_file: String,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text)?;
        let cfg = j.get("config").context("manifest: no config")?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(|v| v.as_usize()).with_context(|| format!("manifest: config.{k}"))
        };
        let params = j
            .get("params")
            .and_then(|p| p.as_arr())
            .context("manifest: params")?
            .iter()
            .map(|p| {
                let name = p.get("name").and_then(|v| v.as_str()).unwrap_or_default().to_string();
                let shape = p
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default();
                (name, shape)
            })
            .collect();
        let arts = j.get("artifacts").context("manifest: artifacts")?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            grid: get("grid")?,
            batch: get("batch")?,
            width: get("width")?,
            modes: get("modes")?,
            layers: get("layers")?,
            lr: j.get("lr").and_then(|v| v.as_f64()).unwrap_or(1e-3),
            params,
            forward_file: arts
                .get("forward")
                .and_then(|v| v.as_str())
                .context("manifest: artifacts.forward")?
                .to_string(),
            train_step_file: arts
                .get("train_step")
                .and_then(|v| v.as_str())
                .context("manifest: artifacts.train_step")?
                .to_string(),
        })
    }

    /// Default artifacts directory, overridable via `SKR_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SKR_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load the initial parameter tensors (f32) in ABI order.
    pub fn load_params(&self) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(self.params.len());
        for (name, shape) in &self.params {
            let arr = npy::read(&self.dir.join("params").join(format!("{name}.npy")))
                .with_context(|| format!("param {name}"))?;
            anyhow::ensure!(&arr.shape == shape, "param {name}: shape {:?} != manifest {:?}", arr.shape, shape);
            out.push(arr.as_f32());
        }
        Ok(out)
    }

    /// Total parameter count (for reporting).
    pub fn num_weights(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_built() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.grid >= 8);
        assert!(m.batch >= 1);
        assert_eq!(m.params.first().map(|(n, _)| n.as_str()), Some("lift_w"));
        let ps = m.load_params().unwrap();
        assert_eq!(ps.len(), m.params.len());
        assert!(m.num_weights() > 1000);
    }
}
