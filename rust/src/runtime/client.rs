//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO **text**
//! (the id-safe interchange format — see DESIGN.md), compile once, execute
//! many times.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Human tag for error messages.
    pub name: String,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }
}

impl Executable {
    /// Execute with literal inputs; the artifact was lowered with
    /// `return_tuple=True`, so the single output buffer is a tuple literal
    /// decomposed into its elements.
    pub fn call<L: std::borrow::Borrow<xla::Literal>>(&self, args: &[L]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let mut lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching outputs of {}", self.name))?;
        let parts = lit.decompose_tuple().context("decomposing output tuple")?;
        Ok(parts)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let count: usize = dims.iter().product();
    anyhow::ensure!(count == data.len(), "literal shape {:?} != data len {}", dims, data.len());
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims_i64)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a flat f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
