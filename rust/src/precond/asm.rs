//! Overlapping additive Schwarz (ASM) preconditioner.
//!
//! Contiguous row blocks extended by `overlap` rows on each side; each local
//! submatrix is solved by a local ILU(0). We use the *restricted* additive
//! Schwarz update (solve on the overlapped domain, write back only the owned
//! rows) — PETSc's default, which avoids double-counting in the overlap.

use super::{Ilu0, Preconditioner};
use crate::la::Csr;
use anyhow::Result;

/// Restricted additive Schwarz with local ILU(0) solves.
pub struct Asm {
    /// Owned (non-overlapping) range per block.
    owned: Vec<(usize, usize)>,
    /// Extended (overlapped) range per block.
    extended: Vec<(usize, usize)>,
    /// Local ILU factorizations of the extended submatrices.
    locals: Vec<Ilu0>,
    /// Scratch sizing.
    max_len: usize,
}

impl Asm {
    pub fn new(a: &Csr, nblocks: usize, overlap: usize) -> Result<Asm> {
        let n = a.nrows();
        let nblocks = nblocks.clamp(1, n.max(1));
        let base = n / nblocks;
        let rem = n % nblocks;
        let mut owned = Vec::with_capacity(nblocks);
        let mut start = 0;
        for b in 0..nblocks {
            let len = base + usize::from(b < rem);
            owned.push((start, start + len));
            start += len;
        }
        let mut extended = Vec::with_capacity(nblocks);
        let mut locals = Vec::with_capacity(nblocks);
        let mut max_len = 0;
        for &(s, e) in &owned {
            let xs = s.saturating_sub(overlap);
            let xe = (e + overlap).min(n);
            extended.push((xs, xe));
            max_len = max_len.max(xe - xs);
            // Extract the local principal submatrix on [xs, xe).
            let mut trips = Vec::new();
            for i in xs..xe {
                let (cols, vals) = a.row(i);
                let mut has_diag = false;
                for (&c, &v) in cols.iter().zip(vals) {
                    if c >= xs && c < xe {
                        trips.push((i - xs, c - xs, v));
                        if c == i {
                            has_diag = true;
                        }
                    }
                }
                if !has_diag {
                    trips.push((i - xs, i - xs, 1.0));
                }
            }
            let local = Csr::from_triplets(xe - xs, xe - xs, &trips);
            locals.push(Ilu0::new(&local)?);
        }
        Ok(Asm { owned, extended, locals, max_len })
    }
}

impl Preconditioner for Asm {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let mut rloc = vec![0.0; self.max_len];
        let mut zloc = vec![0.0; self.max_len];
        for ((&(s, e), &(xs, xe)), local) in
            self.owned.iter().zip(&self.extended).zip(&self.locals)
        {
            let len = xe - xs;
            rloc[..len].copy_from_slice(&r[xs..xe]);
            local.solve_into(&rloc[..len], &mut zloc[..len]);
            // Restricted update: write only the owned rows.
            z[s..e].copy_from_slice(&zloc[s - xs..e - xs]);
        }
    }

    fn name(&self) -> &'static str {
        "asm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn single_block_no_overlap_is_ilu() {
        let a = nonsym(16);
        let asm = Asm::new(&a, 1, 0).unwrap();
        let ilu = Ilu0::new(&a).unwrap();
        let r: Vec<f64> = (0..16).map(|i| (i as f64).sin()).collect();
        let (mut z1, mut z2) = (vec![0.0; 16], vec![0.0; 16]);
        asm.apply(&r, &mut z1);
        ilu.apply(&r, &mut z2);
        for (u, v) in z1.iter().zip(&z2) {
            assert!((u - v).abs() < 1e-14);
        }
    }

    #[test]
    fn overlap_accelerates_gmres() {
        // The meaningful property: as a preconditioner inside GMRES, ASM with
        // overlap should need no more iterations than zero-overlap ASM.
        use crate::solver::{gmres, SolverConfig};
        let a = lap1d(128);
        let with = Asm::new(&a, 8, 6).unwrap();
        let without = Asm::new(&a, 8, 0).unwrap();
        let b = vec![1.0; 128];
        let cfg = SolverConfig::default().with_tol(1e-9);
        let mut x1 = vec![0.0; 128];
        let s1 = gmres(&a, &b, &mut x1, &with, &cfg);
        let mut x2 = vec![0.0; 128];
        let s2 = gmres(&a, &b, &mut x2, &without, &cfg);
        assert!(s1.converged() && s2.converged());
        assert!(s1.iters <= s2.iters, "overlap {} vs none {}", s1.iters, s2.iters);
    }

    #[test]
    fn covers_all_rows_exactly_once() {
        let a = lap1d(37);
        let asm = Asm::new(&a, 5, 2).unwrap();
        let mut covered = vec![0usize; 37];
        for &(s, e) in &asm.owned {
            for c in covered.iter_mut().take(e).skip(s) {
                *c += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1));
    }
}
