//! Overlapping additive Schwarz (ASM) preconditioner.
//!
//! Contiguous row blocks extended by `overlap` rows on each side; each local
//! submatrix is solved by a local ILU(0). We use the *restricted* additive
//! Schwarz update (solve on the overlapped domain, write back only the owned
//! rows) — PETSc's default, which avoids double-counting in the overlap.
//!
//! The subdomain index maps (block ranges, local sparsity patterns, and the
//! scatter from A's value array into each local submatrix) are all functions
//! of the shared [`Sparsity`], so they live in [`AsmSymbolic`] and are built
//! once per structure; `refactor` only stamps values and re-runs the local
//! numeric ILU(0) sweeps.

use super::ilu0::IluSymbolic;
use super::{Ilu0, Preconditioner};
use crate::la::{Csr, Sparsity};
use anyhow::Result;
use std::sync::Arc;

/// Restricted additive Schwarz with local ILU(0) solves.
pub struct Asm {
    /// Owned (non-overlapping) range per block.
    owned: Vec<(usize, usize)>,
    /// Extended (overlapped) range per block.
    extended: Vec<(usize, usize)>,
    /// Local ILU factorizations of the extended submatrices.
    locals: Vec<Ilu0>,
    /// Scratch sizing.
    max_len: usize,
}

/// One subdomain's structural data: local pattern, the scatter from A's
/// value array (`usize::MAX` marks an inserted unit diagonal), and the local
/// ILU(0) symbolic phase.
#[derive(Debug, Clone)]
struct AsmBlock {
    sparsity: Arc<Sparsity>,
    stamp: Vec<usize>,
    ilu: IluSymbolic,
}

/// Structural half of ASM, reusable across every system with this sparsity.
#[derive(Debug, Clone)]
pub struct AsmSymbolic {
    owned: Vec<(usize, usize)>,
    extended: Vec<(usize, usize)>,
    max_len: usize,
    blocks: Vec<AsmBlock>,
}

impl AsmSymbolic {
    pub fn new(sp: &Sparsity, nblocks: usize, overlap: usize) -> Result<AsmSymbolic> {
        let n = sp.nrows();
        let nblocks = nblocks.clamp(1, n.max(1));
        let base = n / nblocks;
        let rem = n % nblocks;
        let mut owned = Vec::with_capacity(nblocks);
        let mut start = 0;
        for b in 0..nblocks {
            let len = base + usize::from(b < rem);
            owned.push((start, start + len));
            start += len;
        }
        let mut extended = Vec::with_capacity(nblocks);
        let mut blocks = Vec::with_capacity(nblocks);
        let mut max_len = 0;
        for &(s, e) in &owned {
            let xs = s.saturating_sub(overlap);
            let xe = (e + overlap).min(n);
            extended.push((xs, xe));
            max_len = max_len.max(xe - xs);
            // Local principal submatrix pattern on [xs, xe), with a unit
            // diagonal inserted where the global row has none locally.
            let mut pattern = Vec::new();
            let mut sources = Vec::new();
            for i in xs..xe {
                let mut has_diag = false;
                for k in sp.row_range(i) {
                    let c = sp.col_idx[k];
                    if c >= xs && c < xe {
                        pattern.push((i - xs, c - xs));
                        sources.push((i - xs, c - xs, k));
                        if c == i {
                            has_diag = true;
                        }
                    }
                }
                if !has_diag {
                    pattern.push((i - xs, i - xs));
                    sources.push((i - xs, i - xs, usize::MAX));
                }
            }
            let local = Arc::new(Sparsity::from_pattern(xe - xs, xe - xs, &pattern));
            let mut stamp = vec![usize::MAX; local.nnz()];
            for &(lr, lc, src) in &sources {
                stamp[local.pos(lr, lc).unwrap()] = src;
            }
            let ilu = IluSymbolic::new(&local)?;
            blocks.push(AsmBlock { sparsity: local, stamp, ilu });
        }
        Ok(AsmSymbolic { owned, extended, max_len, blocks })
    }

    /// Numeric rebuild: stamp each subdomain's values and rerun local ILU(0).
    pub fn refactor(&self, a: &Csr) -> Result<Asm> {
        let avals = a.values();
        let mut locals = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            let vals: Vec<f64> = blk
                .stamp
                .iter()
                .map(|&s| if s == usize::MAX { 1.0 } else { avals[s] })
                .collect();
            let local = Csr::with_values(blk.sparsity.clone(), vals)?;
            locals.push(blk.ilu.refactor(&local)?);
        }
        Ok(Asm {
            owned: self.owned.clone(),
            extended: self.extended.clone(),
            locals,
            max_len: self.max_len,
        })
    }
}

impl Asm {
    pub fn new(a: &Csr, nblocks: usize, overlap: usize) -> Result<Asm> {
        AsmSymbolic::new(a.sparsity(), nblocks, overlap)?.refactor(a)
    }
}

impl Preconditioner for Asm {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let mut rloc = vec![0.0; self.max_len];
        let mut zloc = vec![0.0; self.max_len];
        for ((&(s, e), &(xs, xe)), local) in
            self.owned.iter().zip(&self.extended).zip(&self.locals)
        {
            let len = xe - xs;
            rloc[..len].copy_from_slice(&r[xs..xe]);
            local.solve_into(&rloc[..len], &mut zloc[..len]);
            // Restricted update: write only the owned rows.
            z[s..e].copy_from_slice(&zloc[s - xs..e - xs]);
        }
    }

    fn name(&self) -> &'static str {
        "asm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn single_block_no_overlap_is_ilu() {
        let a = nonsym(16);
        let asm = Asm::new(&a, 1, 0).unwrap();
        let ilu = Ilu0::new(&a).unwrap();
        let r: Vec<f64> = (0..16).map(|i| (i as f64).sin()).collect();
        let (mut z1, mut z2) = (vec![0.0; 16], vec![0.0; 16]);
        asm.apply(&r, &mut z1);
        ilu.apply(&r, &mut z2);
        for (u, v) in z1.iter().zip(&z2) {
            assert!((u - v).abs() < 1e-14);
        }
    }

    #[test]
    fn overlap_accelerates_gmres() {
        // The meaningful property: as a preconditioner inside GMRES, ASM with
        // overlap should need no more iterations than zero-overlap ASM.
        use crate::solver::{gmres, SolverConfig};
        let a = lap1d(128);
        let with = Asm::new(&a, 8, 6).unwrap();
        let without = Asm::new(&a, 8, 0).unwrap();
        let b = vec![1.0; 128];
        let cfg = SolverConfig::default().with_tol(1e-9);
        let mut x1 = vec![0.0; 128];
        let s1 = gmres(&a, &b, &mut x1, &with, &cfg);
        let mut x2 = vec![0.0; 128];
        let s2 = gmres(&a, &b, &mut x2, &without, &cfg);
        assert!(s1.converged() && s2.converged());
        assert!(s1.iters <= s2.iters, "overlap {} vs none {}", s1.iters, s2.iters);
    }

    #[test]
    fn covers_all_rows_exactly_once() {
        let a = lap1d(37);
        let asm = Asm::new(&a, 5, 2).unwrap();
        let mut covered = vec![0usize; 37];
        for &(s, e) in &asm.owned {
            for c in covered.iter_mut().take(e).skip(s) {
                *c += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1));
    }

    #[test]
    fn symbolic_refactor_matches_fresh_build() {
        let a = lap1d(40);
        let sym = AsmSymbolic::new(a.sparsity(), 4, 2).unwrap();
        for shift in [0.0, 0.5] {
            let b = a.add_diag(shift);
            let fresh = Asm::new(&b, 4, 2).unwrap();
            let reused = sym.refactor(&b).unwrap();
            let r: Vec<f64> = (0..40).map(|i| (i as f64 * 0.7).cos()).collect();
            let (mut z1, mut z2) = (vec![0.0; 40], vec![0.0; 40]);
            fresh.apply(&r, &mut z1);
            reused.apply(&r, &mut z2);
            for (u, v) in z1.iter().zip(&z2) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }
}
