//! Identity preconditioner ("None" in the paper's tables).

use super::Preconditioner;

/// z = r.
#[derive(Debug, Clone, Copy, Default)]
pub struct Identity;

impl Preconditioner for Identity {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }

    fn name(&self) -> &'static str {
        "none"
    }
}
