//! SOR / SSOR preconditioner: one symmetric successive-over-relaxation sweep
//! from a zero initial guess — a linear map in r, as required of a
//! preconditioner for (F)GMRES.

use super::Preconditioner;
use crate::la::Csr;
use anyhow::{bail, Result};

/// SSOR sweep preconditioner with relaxation factor ω ∈ (0, 2).
#[derive(Debug, Clone)]
pub struct Sor {
    a: Csr,
    inv_diag: Vec<f64>,
    omega: f64,
}

impl Sor {
    pub fn new(a: &Csr, omega: f64) -> Result<Sor> {
        if !(0.0 < omega && omega < 2.0) {
            bail!("SOR: omega must be in (0,2), got {omega}");
        }
        let d = a.diag();
        let mut inv_diag = Vec::with_capacity(d.len());
        for (i, &di) in d.iter().enumerate() {
            if di == 0.0 {
                bail!("SOR: zero diagonal at row {i}");
            }
            inv_diag.push(1.0 / di);
        }
        Ok(Sor { a: a.clone(), inv_diag, omega })
    }
}

impl Preconditioner for Sor {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = r.len();
        let w = self.omega;
        z.fill(0.0);
        // Forward Gauss–Seidel/SOR sweep (z starts at 0, so only j<i terms
        // contribute).
        for i in 0..n {
            let (cols, vals) = self.a.row(i);
            let mut s = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                if c < i {
                    s += v * z[c];
                }
            }
            z[i] = w * (r[i] - s) * self.inv_diag[i];
        }
        // Backward sweep over the full residual.
        for i in (0..n).rev() {
            let (cols, vals) = self.a.row(i);
            let mut s = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                s += v * z[c];
            }
            z[i] += w * (r[i] - s) * self.inv_diag[i];
        }
    }

    fn name(&self) -> &'static str {
        "sor"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::lap1d;

    #[test]
    fn apply_is_linear() {
        let a = lap1d(16);
        let p = Sor::new(&a, 1.3).unwrap();
        let r1: Vec<f64> = (0..16).map(|i| (i as f64).sin()).collect();
        let r2: Vec<f64> = (0..16).map(|i| (i as f64).cos()).collect();
        let (mut z1, mut z2, mut z12) = (vec![0.0; 16], vec![0.0; 16], vec![0.0; 16]);
        p.apply(&r1, &mut z1);
        p.apply(&r2, &mut z2);
        let sum: Vec<f64> = r1.iter().zip(&r2).map(|(a, b)| 2.0 * a + b).collect();
        p.apply(&sum, &mut z12);
        for i in 0..16 {
            assert!((z12[i] - (2.0 * z1[i] + z2[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn improves_residual_on_spd() {
        // One SSOR application should reduce ||r - A z|| vs z = 0.
        let a = lap1d(32);
        let p = Sor::new(&a, 1.5).unwrap();
        let r = vec![1.0; 32];
        let mut z = vec![0.0; 32];
        p.apply(&r, &mut z);
        let az = a.matvec(&z);
        let res: f64 = r.iter().zip(&az).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
        assert!(res < crate::la::norm2(&r), "res {res}");
    }

    #[test]
    fn rejects_bad_omega() {
        let a = lap1d(4);
        assert!(Sor::new(&a, 0.0).is_err());
        assert!(Sor::new(&a, 2.0).is_err());
    }
}
