//! Block-Jacobi preconditioner: contiguous row blocks, each solved exactly
//! by a dense LU factored at setup.

use super::Preconditioner;
use crate::la::{Csr, Mat};
use anyhow::{bail, Result};

/// Per-block dense LU factors (PA = LU compact storage) for contiguous
/// blocks covering 0..n.
#[derive(Debug, Clone)]
pub struct BlockJacobi {
    /// (start, end) row range per block.
    ranges: Vec<(usize, usize)>,
    /// Factored dense blocks: compact LU with pivot vectors.
    factors: Vec<LuFactor>,
}

#[derive(Debug, Clone)]
struct LuFactor {
    lu: Mat,
    piv: Vec<usize>,
}

impl LuFactor {
    fn new(mut a: Mat) -> Result<LuFactor> {
        let n = a.nrows;
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            let mut p = k;
            for i in k + 1..n {
                if a[(i, k)].abs() > a[(p, k)].abs() {
                    p = i;
                }
            }
            if a[(p, k)].abs() < 1e-300 {
                bail!("BlockJacobi: singular diagonal block");
            }
            if p != k {
                for j in 0..n {
                    let (u, v) = (a[(k, j)], a[(p, j)]);
                    a[(k, j)] = v;
                    a[(p, j)] = u;
                }
                piv.swap(k, p);
            }
            for i in k + 1..n {
                let l = a[(i, k)] / a[(k, k)];
                a[(i, k)] = l;
                for j in k + 1..n {
                    let akj = a[(k, j)];
                    a[(i, j)] -= l * akj;
                }
            }
        }
        Ok(LuFactor { lu: a, piv })
    }

    fn solve_into(&self, b: &[f64], x: &mut [f64]) {
        let n = self.lu.nrows;
        for i in 0..n {
            x[i] = b[self.piv[i]];
        }
        for i in 0..n {
            for j in 0..i {
                let lij = self.lu[(i, j)];
                x[i] -= lij * x[j];
            }
        }
        for i in (0..n).rev() {
            for j in i + 1..n {
                let uij = self.lu[(i, j)];
                x[i] -= uij * x[j];
            }
            x[i] /= self.lu[(i, i)];
        }
    }
}

impl BlockJacobi {
    /// Split `a` into `nblocks` contiguous row blocks.
    pub fn new(a: &Csr, nblocks: usize) -> Result<BlockJacobi> {
        let n = a.nrows();
        let nblocks = nblocks.clamp(1, n.max(1));
        let mut ranges = Vec::with_capacity(nblocks);
        let base = n / nblocks;
        let rem = n % nblocks;
        let mut start = 0;
        for b in 0..nblocks {
            let len = base + usize::from(b < rem);
            ranges.push((start, start + len));
            start += len;
        }
        let mut factors = Vec::with_capacity(nblocks);
        for &(s, e) in &ranges {
            let len = e - s;
            let mut block = Mat::zeros(len, len);
            for i in s..e {
                let (cols, vals) = a.row(i);
                for (&c, &v) in cols.iter().zip(vals) {
                    if c >= s && c < e {
                        block[(i - s, c - s)] = v;
                    }
                }
            }
            factors.push(LuFactor::new(block)?);
        }
        Ok(BlockJacobi { ranges, factors })
    }
}

impl Preconditioner for BlockJacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for (&(s, e), f) in self.ranges.iter().zip(&self.factors) {
            f.solve_into(&r[s..e], &mut z[s..e]);
        }
    }

    fn name(&self) -> &'static str {
        "bjacobi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn one_block_is_direct_solve() {
        let a = nonsym(20);
        let p = BlockJacobi::new(&a, 1).unwrap();
        let xtrue: Vec<f64> = (0..20).map(|i| (i as f64 * 0.7).cos()).collect();
        let b = a.matvec(&xtrue);
        let mut z = vec![0.0; 20];
        p.apply(&b, &mut z);
        for (u, v) in z.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn many_blocks_apply_blockwise() {
        let a = lap1d(10);
        let p = BlockJacobi::new(&a, 5).unwrap();
        // Each 2x2 block of the 1-D Laplacian is [[2,-1],[-1,2]].
        let r = vec![1.0; 10];
        let mut z = vec![0.0; 10];
        p.apply(&r, &mut z);
        // Solve [[2,-1],[-1,2]] x = [1,1] → x = [1,1].
        for &v in &z {
            assert!((v - 1.0).abs() < 1e-12, "{z:?}");
        }
    }

    #[test]
    fn block_count_is_clamped() {
        let a = lap1d(3);
        let p = BlockJacobi::new(&a, 100).unwrap();
        assert_eq!(p.ranges.len(), 3);
    }
}
