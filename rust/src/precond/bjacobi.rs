//! Block-Jacobi preconditioner: contiguous row blocks, each solved exactly
//! by a dense LU factored at setup.
//!
//! The block layout and the scatter from A's value array into each dense
//! block are functions of the shared [`Sparsity`] and live in
//! [`BjSymbolic`]; `refactor` stamps values and reruns the dense LU per
//! system.

use super::Preconditioner;
use crate::la::{Csr, Mat, Sparsity};
use anyhow::{bail, Result};

/// Per-block dense LU factors (PA = LU compact storage) for contiguous
/// blocks covering 0..n.
#[derive(Debug, Clone)]
pub struct BlockJacobi {
    /// (start, end) row range per block.
    ranges: Vec<(usize, usize)>,
    /// Factored dense blocks: compact LU with pivot vectors.
    factors: Vec<LuFactor>,
}

#[derive(Debug, Clone)]
struct LuFactor {
    lu: Mat,
    piv: Vec<usize>,
}

impl LuFactor {
    fn new(mut a: Mat) -> Result<LuFactor> {
        let n = a.nrows;
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            let mut p = k;
            for i in k + 1..n {
                if a[(i, k)].abs() > a[(p, k)].abs() {
                    p = i;
                }
            }
            if a[(p, k)].abs() < 1e-300 {
                bail!("BlockJacobi: singular diagonal block");
            }
            if p != k {
                for j in 0..n {
                    let (u, v) = (a[(k, j)], a[(p, j)]);
                    a[(k, j)] = v;
                    a[(p, j)] = u;
                }
                piv.swap(k, p);
            }
            for i in k + 1..n {
                let l = a[(i, k)] / a[(k, k)];
                a[(i, k)] = l;
                for j in k + 1..n {
                    let akj = a[(k, j)];
                    a[(i, j)] -= l * akj;
                }
            }
        }
        Ok(LuFactor { lu: a, piv })
    }

    fn solve_into(&self, b: &[f64], x: &mut [f64]) {
        let n = self.lu.nrows;
        for i in 0..n {
            x[i] = b[self.piv[i]];
        }
        for i in 0..n {
            for j in 0..i {
                let lij = self.lu[(i, j)];
                x[i] -= lij * x[j];
            }
        }
        for i in (0..n).rev() {
            for j in i + 1..n {
                let uij = self.lu[(i, j)];
                x[i] -= uij * x[j];
            }
            x[i] /= self.lu[(i, i)];
        }
    }
}

/// Structural half of block-Jacobi: block ranges plus, per block, the
/// (dense row, dense col, A value index) scatter triples.
#[derive(Debug, Clone)]
pub struct BjSymbolic {
    ranges: Vec<(usize, usize)>,
    scatter: Vec<Vec<(usize, usize, usize)>>,
}

impl BjSymbolic {
    pub fn new(sp: &Sparsity, nblocks: usize) -> BjSymbolic {
        let n = sp.nrows();
        let nblocks = nblocks.clamp(1, n.max(1));
        let mut ranges = Vec::with_capacity(nblocks);
        let base = n / nblocks;
        let rem = n % nblocks;
        let mut start = 0;
        for b in 0..nblocks {
            let len = base + usize::from(b < rem);
            ranges.push((start, start + len));
            start += len;
        }
        let mut scatter = Vec::with_capacity(nblocks);
        for &(s, e) in &ranges {
            let mut triples = Vec::new();
            for i in s..e {
                for k in sp.row_range(i) {
                    let c = sp.col_idx[k];
                    if c >= s && c < e {
                        triples.push((i - s, c - s, k));
                    }
                }
            }
            scatter.push(triples);
        }
        BjSymbolic { ranges, scatter }
    }

    /// Numeric rebuild: stamp each dense block and refactor its LU.
    pub fn refactor(&self, a: &Csr) -> Result<BlockJacobi> {
        let avals = a.values();
        let mut factors = Vec::with_capacity(self.ranges.len());
        for (&(s, e), triples) in self.ranges.iter().zip(&self.scatter) {
            let len = e - s;
            let mut block = Mat::zeros(len, len);
            for &(br, bc, src) in triples {
                block[(br, bc)] = avals[src];
            }
            factors.push(LuFactor::new(block)?);
        }
        Ok(BlockJacobi { ranges: self.ranges.clone(), factors })
    }
}

impl BlockJacobi {
    /// Split `a` into `nblocks` contiguous row blocks.
    pub fn new(a: &Csr, nblocks: usize) -> Result<BlockJacobi> {
        BjSymbolic::new(a.sparsity(), nblocks).refactor(a)
    }
}

impl Preconditioner for BlockJacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for (&(s, e), f) in self.ranges.iter().zip(&self.factors) {
            f.solve_into(&r[s..e], &mut z[s..e]);
        }
    }

    fn name(&self) -> &'static str {
        "bjacobi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn one_block_is_direct_solve() {
        let a = nonsym(20);
        let p = BlockJacobi::new(&a, 1).unwrap();
        let xtrue: Vec<f64> = (0..20).map(|i| (i as f64 * 0.7).cos()).collect();
        let b = a.matvec(&xtrue);
        let mut z = vec![0.0; 20];
        p.apply(&b, &mut z);
        for (u, v) in z.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn many_blocks_apply_blockwise() {
        let a = lap1d(10);
        let p = BlockJacobi::new(&a, 5).unwrap();
        // Each 2x2 block of the 1-D Laplacian is [[2,-1],[-1,2]].
        let r = vec![1.0; 10];
        let mut z = vec![0.0; 10];
        p.apply(&r, &mut z);
        // Solve [[2,-1],[-1,2]] x = [1,1] → x = [1,1].
        for &v in &z {
            assert!((v - 1.0).abs() < 1e-12, "{z:?}");
        }
    }

    #[test]
    fn block_count_is_clamped() {
        let a = lap1d(3);
        let p = BlockJacobi::new(&a, 100).unwrap();
        assert_eq!(p.ranges.len(), 3);
    }

    #[test]
    fn symbolic_refactor_matches_fresh_build() {
        let a = nonsym(30);
        let sym = BjSymbolic::new(a.sparsity(), 6);
        let b = a.add_diag(0.75);
        let fresh = BlockJacobi::new(&b, 6).unwrap();
        let reused = sym.refactor(&b).unwrap();
        let r: Vec<f64> = (0..30).map(|i| (i as f64 * 0.3).sin()).collect();
        let (mut z1, mut z2) = (vec![0.0; 30], vec![0.0; 30]);
        fresh.apply(&r, &mut z1);
        reused.apply(&r, &mut z2);
        for (u, v) in z1.iter().zip(&z2) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }
}
