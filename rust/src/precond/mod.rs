//! Preconditioners — the seven the paper benchmarks (Appendix D.3):
//! None, Jacobi, Block-Jacobi, SOR (SSOR sweep), ASM (overlapping additive
//! Schwarz with local ILU(0)), ICC(0) and ILU(0).
//!
//! All are used as **right** preconditioners: the solvers iterate on
//! A M⁻¹ y = b, x = M⁻¹ y, matching PETSc's default side for GMRES in the
//! paper's setup.

mod asm;
mod bjacobi;
mod icc0;
mod identity;
mod ilu0;
mod jacobi;
mod sor;

pub use asm::Asm;
pub use bjacobi::BlockJacobi;
pub use icc0::Icc0;
pub use identity::Identity;
pub use ilu0::Ilu0;
pub use jacobi::Jacobi;
pub use sor::Sor;

use crate::la::Csr;
use anyhow::Result;

/// A preconditioner application z = M⁻¹ r.
pub trait Preconditioner: Send + Sync {
    /// Apply into a caller-provided buffer (hot path; must not allocate).
    fn apply(&self, r: &[f64], z: &mut [f64]);

    /// Human-readable kind tag.
    fn name(&self) -> &'static str;
}

/// The preconditioner menu keyed by the paper's names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecondKind {
    None,
    Jacobi,
    BJacobi,
    Sor,
    Asm,
    Icc,
    Ilu,
}

impl PrecondKind {
    pub const ALL: [PrecondKind; 7] = [
        PrecondKind::None,
        PrecondKind::Jacobi,
        PrecondKind::BJacobi,
        PrecondKind::Sor,
        PrecondKind::Asm,
        PrecondKind::Icc,
        PrecondKind::Ilu,
    ];

    pub fn parse(s: &str) -> Result<PrecondKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" => PrecondKind::None,
            "jacobi" => PrecondKind::Jacobi,
            "bjacobi" => PrecondKind::BJacobi,
            "sor" => PrecondKind::Sor,
            "asm" => PrecondKind::Asm,
            "icc" => PrecondKind::Icc,
            "ilu" => PrecondKind::Ilu,
            other => anyhow::bail!("unknown preconditioner {other:?}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PrecondKind::None => "None",
            PrecondKind::Jacobi => "Jacobi",
            PrecondKind::BJacobi => "BJacobi",
            PrecondKind::Sor => "SOR",
            PrecondKind::Asm => "ASM",
            PrecondKind::Icc => "ICC",
            PrecondKind::Ilu => "ILU",
        }
    }

    /// Construct the preconditioner for a given matrix.
    pub fn build(&self, a: &Csr) -> Result<Box<dyn Preconditioner>> {
        Ok(match self {
            PrecondKind::None => Box::new(Identity),
            PrecondKind::Jacobi => Box::new(Jacobi::new(a)?),
            PrecondKind::BJacobi => Box::new(BlockJacobi::new(a, default_blocks(a.nrows()))?),
            PrecondKind::Sor => Box::new(Sor::new(a, 1.5)?),
            PrecondKind::Asm => Box::new(Asm::new(a, default_blocks(a.nrows()), overlap_for(a.nrows()))?),
            PrecondKind::Icc => Box::new(Icc0::new(a)?),
            PrecondKind::Ilu => Box::new(Ilu0::new(a)?),
        })
    }
}

fn default_blocks(n: usize) -> usize {
    // PETSc's bjacobi default is one block per rank; sequentially we use a
    // modest block count that scales mildly with n.
    ((n as f64).sqrt() as usize / 8).clamp(4, 64)
}

fn overlap_for(n: usize) -> usize {
    ((n as f64).sqrt() as usize / 32).clamp(1, 8)
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::la::Csr;

    /// 1-D Laplacian (tridiag [-1, 2, -1]) — SPD test matrix.
    pub fn lap1d(n: usize) -> Csr {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 2.0));
            if i > 0 {
                t.push((i, i - 1, -1.0));
            }
            if i + 1 < n {
                t.push((i, i + 1, -1.0));
            }
        }
        Csr::from_triplets(n, n, &t)
    }

    /// Nonsymmetric convection-diffusion-like tridiagonal matrix.
    pub fn nonsym(n: usize) -> Csr {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 3.0));
            if i > 0 {
                t.push((i, i - 1, -1.4));
            }
            if i + 1 < n {
                t.push((i, i + 1, -0.6));
            }
        }
        Csr::from_triplets(n, n, &t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::*;

    /// Every preconditioner must be a linear, nonsingular map that reduces
    /// the condition of the iteration in practice; here we sanity-check
    /// apply() against direct expectations where possible.
    #[test]
    fn all_kinds_build_and_apply() {
        let a = nonsym(64);
        for kind in PrecondKind::ALL {
            let p = kind.build(&a).unwrap();
            let r = vec![1.0; 64];
            let mut z = vec![0.0; 64];
            p.apply(&r, &mut z);
            assert!(z.iter().all(|v| v.is_finite()), "{kind:?}");
            // M⁻¹ r must be nonzero for nonzero r.
            assert!(crate::la::norm2(&z) > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn parse_labels_roundtrip() {
        for kind in PrecondKind::ALL {
            let back = PrecondKind::parse(kind.label()).unwrap();
            assert_eq!(back, kind);
        }
        assert!(PrecondKind::parse("bogus").is_err());
    }

    #[test]
    fn identity_is_identity() {
        let a = lap1d(8);
        let p = PrecondKind::None.build(&a).unwrap();
        let r: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let mut z = vec![0.0; 8];
        p.apply(&r, &mut z);
        assert_eq!(z, r);
    }
}
