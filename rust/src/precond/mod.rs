//! Preconditioners — the seven the paper benchmarks (Appendix D.3):
//! None, Jacobi, Block-Jacobi, SOR (SSOR sweep), ASM (overlapping additive
//! Schwarz with local ILU(0)), ICC(0) and ILU(0).
//!
//! All are used as **right** preconditioners: the solvers iterate on
//! A M⁻¹ y = b, x = M⁻¹ y, matching PETSc's default side for GMRES in the
//! paper's setup.
//!
//! Construction is two-phase: [`PrecondKind::symbolic`] analyses the shared
//! [`Sparsity`] once (ILU0/ICC0 fill positions, ASM subdomain maps,
//! BlockJacobi block layout) and [`SymbolicPrecond::refactor`] stamps one
//! system's values — the sequence drivers cache the symbolic phase across a
//! sorted shard. [`PrecondKind::build`] composes the two, so fresh builds
//! and cached reuse share a single code path and are bit-identical.

mod asm;
mod bjacobi;
mod icc0;
mod identity;
mod ilu0;
mod jacobi;
mod sor;

pub use asm::{Asm, AsmSymbolic};
pub use bjacobi::{BjSymbolic, BlockJacobi};
pub use icc0::{Icc0, IccSymbolic};
pub use identity::Identity;
pub use ilu0::{Ilu0, IluSymbolic};
pub use jacobi::Jacobi;
pub use sor::Sor;

use crate::la::{Csr, Sparsity};
use anyhow::Result;
use std::sync::Arc;

/// A preconditioner application z = M⁻¹ r.
pub trait Preconditioner: Send + Sync {
    /// Apply into a caller-provided buffer (hot path; must not allocate).
    fn apply(&self, r: &[f64], z: &mut [f64]);

    /// Human-readable kind tag.
    fn name(&self) -> &'static str;
}

/// The preconditioner menu keyed by the paper's names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecondKind {
    None,
    Jacobi,
    BJacobi,
    Sor,
    Asm,
    Icc,
    Ilu,
}

impl PrecondKind {
    pub const ALL: [PrecondKind; 7] = [
        PrecondKind::None,
        PrecondKind::Jacobi,
        PrecondKind::BJacobi,
        PrecondKind::Sor,
        PrecondKind::Asm,
        PrecondKind::Icc,
        PrecondKind::Ilu,
    ];

    pub fn parse(s: &str) -> Result<PrecondKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" => PrecondKind::None,
            "jacobi" => PrecondKind::Jacobi,
            "bjacobi" => PrecondKind::BJacobi,
            "sor" => PrecondKind::Sor,
            "asm" => PrecondKind::Asm,
            "icc" => PrecondKind::Icc,
            "ilu" => PrecondKind::Ilu,
            other => anyhow::bail!("unknown preconditioner {other:?}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PrecondKind::None => "None",
            PrecondKind::Jacobi => "Jacobi",
            PrecondKind::BJacobi => "BJacobi",
            PrecondKind::Sor => "SOR",
            PrecondKind::Asm => "ASM",
            PrecondKind::Icc => "ICC",
            PrecondKind::Ilu => "ILU",
        }
    }

    /// Symbolic phase keyed on the shared structure: fill positions, index
    /// maps and block layouts that every same-sparsity system reuses.
    pub fn symbolic(&self, sparsity: &Arc<Sparsity>) -> Result<SymbolicPrecond> {
        let n = sparsity.nrows();
        let inner = match self {
            PrecondKind::None => Symbolic::None,
            PrecondKind::Jacobi => Symbolic::Jacobi,
            PrecondKind::BJacobi => Symbolic::BJacobi(BjSymbolic::new(sparsity, default_blocks(n))),
            PrecondKind::Sor => Symbolic::Sor,
            PrecondKind::Asm => {
                Symbolic::Asm(AsmSymbolic::new(sparsity, default_blocks(n), overlap_for(n))?)
            }
            PrecondKind::Icc => Symbolic::Icc(IccSymbolic::new(sparsity)?),
            PrecondKind::Ilu => Symbolic::Ilu(IluSymbolic::new(sparsity)?),
        };
        Ok(SymbolicPrecond { kind: *self, sparsity: sparsity.clone(), inner })
    }

    /// Construct the preconditioner for a given matrix. One-shot convenience:
    /// symbolic phase on the matrix's own structure, then numeric refactor —
    /// the exact code path sequence drivers take per system, so cached-reuse
    /// and fresh builds are bit-identical by construction.
    pub fn build(&self, a: &Csr) -> Result<Box<dyn Preconditioner>> {
        self.symbolic(a.sparsity())?.refactor(a)
    }
}

/// A preconditioner's structure-dependent half, built once per sparsity and
/// reused across every system of a sorted sequence via [`SymbolicPrecond::refactor`].
pub struct SymbolicPrecond {
    kind: PrecondKind,
    sparsity: Arc<Sparsity>,
    inner: Symbolic,
}

enum Symbolic {
    None,
    Jacobi,
    BJacobi(BjSymbolic),
    Sor,
    Asm(AsmSymbolic),
    Icc(IccSymbolic),
    Ilu(IluSymbolic),
}

impl SymbolicPrecond {
    pub fn kind(&self) -> PrecondKind {
        self.kind
    }

    /// The structure this symbolic phase was built for.
    pub fn sparsity(&self) -> &Arc<Sparsity> {
        &self.sparsity
    }

    /// Whether `a` can reuse this symbolic phase: pointer-equal structure
    /// (the shared-`Arc` fast path) or an equal pattern.
    pub fn matches(&self, a: &Csr) -> bool {
        Arc::ptr_eq(&self.sparsity, a.sparsity()) || *self.sparsity == **a.sparsity()
    }

    /// Cheap numeric rebuild for one system on the precomputed structure.
    pub fn refactor(&self, a: &Csr) -> Result<Box<dyn Preconditioner>> {
        if !self.matches(a) {
            anyhow::bail!("symbolic {:?} does not match the matrix sparsity", self.kind);
        }
        Ok(match &self.inner {
            Symbolic::None => Box::new(Identity),
            Symbolic::Jacobi => Box::new(Jacobi::new(a)?),
            Symbolic::BJacobi(s) => Box::new(s.refactor(a)?),
            Symbolic::Sor => Box::new(Sor::new(a, 1.5)?),
            Symbolic::Asm(s) => Box::new(s.refactor(a)?),
            Symbolic::Icc(s) => Box::new(s.refactor(a)?),
            Symbolic::Ilu(s) => Box::new(s.refactor(a)?),
        })
    }
}

fn default_blocks(n: usize) -> usize {
    // PETSc's bjacobi default is one block per rank; sequentially we use a
    // modest block count that scales mildly with n.
    ((n as f64).sqrt() as usize / 8).clamp(4, 64)
}

fn overlap_for(n: usize) -> usize {
    ((n as f64).sqrt() as usize / 32).clamp(1, 8)
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::la::Csr;

    /// 1-D Laplacian (tridiag [-1, 2, -1]) — SPD test matrix.
    pub fn lap1d(n: usize) -> Csr {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 2.0));
            if i > 0 {
                t.push((i, i - 1, -1.0));
            }
            if i + 1 < n {
                t.push((i, i + 1, -1.0));
            }
        }
        Csr::from_triplets(n, n, &t)
    }

    /// Nonsymmetric convection-diffusion-like tridiagonal matrix.
    pub fn nonsym(n: usize) -> Csr {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 3.0));
            if i > 0 {
                t.push((i, i - 1, -1.4));
            }
            if i + 1 < n {
                t.push((i, i + 1, -0.6));
            }
        }
        Csr::from_triplets(n, n, &t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::*;

    /// Every preconditioner must be a linear, nonsingular map that reduces
    /// the condition of the iteration in practice; here we sanity-check
    /// apply() against direct expectations where possible.
    #[test]
    fn all_kinds_build_and_apply() {
        let a = nonsym(64);
        for kind in PrecondKind::ALL {
            let p = kind.build(&a).unwrap();
            let r = vec![1.0; 64];
            let mut z = vec![0.0; 64];
            p.apply(&r, &mut z);
            assert!(z.iter().all(|v| v.is_finite()), "{kind:?}");
            // M⁻¹ r must be nonzero for nonzero r.
            assert!(crate::la::norm2(&z) > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn parse_labels_roundtrip() {
        for kind in PrecondKind::ALL {
            let back = PrecondKind::parse(kind.label()).unwrap();
            assert_eq!(back, kind);
        }
        assert!(PrecondKind::parse("bogus").is_err());
    }

    #[test]
    fn symbolic_refactor_equals_fresh_build_for_all_kinds() {
        let a = nonsym(64);
        for kind in PrecondKind::ALL {
            let sym = kind.symbolic(a.sparsity()).unwrap();
            assert_eq!(sym.kind(), kind);
            for shift in [0.0, 0.25] {
                let b = a.add_diag(shift);
                assert!(sym.matches(&b));
                let fresh = kind.build(&b).unwrap();
                let reused = sym.refactor(&b).unwrap();
                let r: Vec<f64> = (0..64).map(|i| (i as f64 * 0.11).sin()).collect();
                let (mut z1, mut z2) = (vec![0.0; 64], vec![0.0; 64]);
                fresh.apply(&r, &mut z1);
                reused.apply(&r, &mut z2);
                for (u, v) in z1.iter().zip(&z2) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{kind:?}");
                }
            }
        }
    }

    #[test]
    fn symbolic_rejects_mismatched_pattern() {
        let sym = PrecondKind::Ilu.symbolic(lap1d(8).sparsity()).unwrap();
        assert!(!sym.matches(&lap1d(9)));
        assert!(sym.refactor(&lap1d(9)).is_err());
    }

    #[test]
    fn identity_is_identity() {
        let a = lap1d(8);
        let p = PrecondKind::None.build(&a).unwrap();
        let r: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let mut z = vec![0.0; 8];
        p.apply(&r, &mut z);
        assert_eq!(z, r);
    }
}
