//! ILU(0): incomplete LU factorization with zero fill-in, IKJ variant on the
//! CSR pattern of A. L is unit lower triangular; L and U share A's sparsity.

use super::Preconditioner;
use crate::la::Csr;
use anyhow::{bail, Result};

/// ILU(0) factors stored in a single CSR copy of A's pattern
/// (strict lower = L without unit diagonal, diagonal+upper = U).
#[derive(Debug, Clone)]
pub struct Ilu0 {
    lu: Csr,
    /// Position of the diagonal entry within each row of `lu`.
    diag_pos: Vec<usize>,
}

impl Ilu0 {
    pub fn new(a: &Csr) -> Result<Ilu0> {
        let n = a.nrows();
        let mut lu = a.clone();
        let mut diag_pos = vec![usize::MAX; n];
        for i in 0..n {
            let (start, end) = (lu.row_ptr[i], lu.row_ptr[i + 1]);
            for k in start..end {
                if lu.col_idx[k] == i {
                    diag_pos[i] = k;
                }
            }
            if diag_pos[i] == usize::MAX {
                bail!("ILU0: structurally zero diagonal at row {i}");
            }
        }
        // IKJ factorization restricted to the pattern.
        for i in 1..n {
            let (start, end) = (lu.row_ptr[i], lu.row_ptr[i + 1]);
            for kk in start..end {
                let k = lu.col_idx[kk];
                if k >= i {
                    break;
                }
                let ukk = lu.vals[diag_pos[k]];
                if ukk == 0.0 {
                    bail!("ILU0: zero pivot at row {k}");
                }
                let lik = lu.vals[kk] / ukk;
                lu.vals[kk] = lik;
                // Subtract lik * U[k, j] for j > k within row i's pattern.
                let krow_end = lu.row_ptr[k + 1];
                let mut p = kk + 1;
                let mut q = diag_pos[k] + 1;
                while p < end && q < krow_end {
                    let (ci, ck) = (lu.col_idx[p], lu.col_idx[q]);
                    if ci == ck {
                        lu.vals[p] -= lik * lu.vals[q];
                        p += 1;
                        q += 1;
                    } else if ci < ck {
                        p += 1;
                    } else {
                        q += 1;
                    }
                }
            }
            if lu.vals[diag_pos[i]] == 0.0 {
                bail!("ILU0: zero pivot produced at row {i}");
            }
        }
        Ok(Ilu0 { lu, diag_pos })
    }

    /// Solve L y = r (unit lower), then U z = y, into `z`.
    pub fn solve_into(&self, r: &[f64], z: &mut [f64]) {
        let n = r.len();
        // Forward: y overwrites z.
        for i in 0..n {
            let (start, _end) = (self.lu.row_ptr[i], self.lu.row_ptr[i + 1]);
            let mut s = r[i];
            for k in start..self.diag_pos[i] {
                s -= self.lu.vals[k] * z[self.lu.col_idx[k]];
            }
            z[i] = s;
        }
        // Backward.
        for i in (0..n).rev() {
            let end = self.lu.row_ptr[i + 1];
            let dp = self.diag_pos[i];
            let mut s = z[i];
            for k in dp + 1..end {
                s -= self.lu.vals[k] * z[self.lu.col_idx[k]];
            }
            z[i] = s / self.lu.vals[dp];
        }
    }
}

impl Preconditioner for Ilu0 {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        self.solve_into(r, z);
    }

    fn name(&self) -> &'static str {
        "ilu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn exact_for_tridiagonal() {
        // A tridiagonal matrix has no fill-in, so ILU(0) == exact LU and the
        // preconditioner solve is a direct solve.
        let a = nonsym(32);
        let p = Ilu0::new(&a).unwrap();
        let xtrue: Vec<f64> = (0..32).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        let b = a.matvec(&xtrue);
        let mut z = vec![0.0; 32];
        p.apply(&b, &mut z);
        for (u, v) in z.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-10, "{u} vs {v}");
        }
    }

    #[test]
    fn exact_for_spd_tridiagonal() {
        let a = lap1d(16);
        let p = Ilu0::new(&a).unwrap();
        let b = vec![1.0; 16];
        let mut z = vec![0.0; 16];
        p.apply(&b, &mut z);
        let az = a.matvec(&z);
        for (u, v) in az.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_missing_diagonal() {
        let a = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 1.0)]);
        assert!(Ilu0::new(&a).is_err());
    }
}
