//! ILU(0): incomplete LU factorization with zero fill-in, IKJ variant on the
//! CSR pattern of A. L is unit lower triangular; L and U share A's sparsity.
//!
//! The factorization is split into a symbolic phase ([`IluSymbolic`]: diagonal
//! positions, keyed on the shared [`Sparsity`]) and a numeric phase
//! (`refactor`: the IKJ sweep over fresh values) so a sorted sequence of
//! same-structure systems pays for the structural analysis once.

use super::Preconditioner;
use crate::la::{Csr, Sparsity};
use anyhow::{bail, Result};
use std::sync::Arc;

/// ILU(0) factors stored in a single CSR copy of A's pattern
/// (strict lower = L without unit diagonal, diagonal+upper = U).
#[derive(Debug, Clone)]
pub struct Ilu0 {
    lu: Csr,
    /// Position of the diagonal entry within each row of `lu`.
    diag_pos: Vec<usize>,
}

/// Structural half of ILU(0): the shared pattern plus per-row diagonal
/// positions, reusable across every system with this sparsity.
#[derive(Debug, Clone)]
pub struct IluSymbolic {
    sparsity: Arc<Sparsity>,
    diag_pos: Vec<usize>,
}

impl IluSymbolic {
    pub fn new(sparsity: &Arc<Sparsity>) -> Result<IluSymbolic> {
        let n = sparsity.nrows();
        let mut diag_pos = vec![usize::MAX; n];
        for (i, dp) in diag_pos.iter_mut().enumerate() {
            match sparsity.diag_pos(i) {
                Some(p) => *dp = p,
                None => bail!("ILU0: structurally zero diagonal at row {i}"),
            }
        }
        Ok(IluSymbolic { sparsity: sparsity.clone(), diag_pos })
    }

    /// Numeric factorization of `a` on the precomputed structure.
    pub fn refactor(&self, a: &Csr) -> Result<Ilu0> {
        debug_assert!(
            Arc::ptr_eq(&self.sparsity, a.sparsity()) || *self.sparsity == **a.sparsity(),
            "ILU0 refactor: sparsity mismatch"
        );
        let n = a.nrows();
        let diag_pos = &self.diag_pos;
        let mut lu = a.clone();
        let (sp, vals) = lu.parts_mut();
        let row_ptr = &sp.row_ptr;
        let col_idx = &sp.col_idx;
        // IKJ factorization restricted to the pattern.
        for i in 1..n {
            let (start, end) = (row_ptr[i], row_ptr[i + 1]);
            for kk in start..end {
                let k = col_idx[kk];
                if k >= i {
                    break;
                }
                let ukk = vals[diag_pos[k]];
                if ukk == 0.0 {
                    bail!("ILU0: zero pivot at row {k}");
                }
                let lik = vals[kk] / ukk;
                vals[kk] = lik;
                // Subtract lik * U[k, j] for j > k within row i's pattern.
                let krow_end = row_ptr[k + 1];
                let mut p = kk + 1;
                let mut q = diag_pos[k] + 1;
                while p < end && q < krow_end {
                    let (ci, ck) = (col_idx[p], col_idx[q]);
                    if ci == ck {
                        vals[p] -= lik * vals[q];
                        p += 1;
                        q += 1;
                    } else if ci < ck {
                        p += 1;
                    } else {
                        q += 1;
                    }
                }
            }
            if vals[diag_pos[i]] == 0.0 {
                bail!("ILU0: zero pivot produced at row {i}");
            }
        }
        Ok(Ilu0 { lu, diag_pos: self.diag_pos.clone() })
    }
}

impl Ilu0 {
    pub fn new(a: &Csr) -> Result<Ilu0> {
        IluSymbolic::new(a.sparsity())?.refactor(a)
    }

    /// Solve L y = r (unit lower), then U z = y, into `z`.
    pub fn solve_into(&self, r: &[f64], z: &mut [f64]) {
        let n = r.len();
        let row_ptr = self.lu.row_offsets();
        let col_idx = self.lu.col_indices();
        let vals = self.lu.values();
        // Forward: y overwrites z.
        for i in 0..n {
            let start = row_ptr[i];
            let mut s = r[i];
            for k in start..self.diag_pos[i] {
                s -= vals[k] * z[col_idx[k]];
            }
            z[i] = s;
        }
        // Backward.
        for i in (0..n).rev() {
            let end = row_ptr[i + 1];
            let dp = self.diag_pos[i];
            let mut s = z[i];
            for k in dp + 1..end {
                s -= vals[k] * z[col_idx[k]];
            }
            z[i] = s / vals[dp];
        }
    }
}

impl Preconditioner for Ilu0 {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        self.solve_into(r, z);
    }

    fn name(&self) -> &'static str {
        "ilu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn exact_for_tridiagonal() {
        // A tridiagonal matrix has no fill-in, so ILU(0) == exact LU and the
        // preconditioner solve is a direct solve.
        let a = nonsym(32);
        let p = Ilu0::new(&a).unwrap();
        let xtrue: Vec<f64> = (0..32).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        let b = a.matvec(&xtrue);
        let mut z = vec![0.0; 32];
        p.apply(&b, &mut z);
        for (u, v) in z.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-10, "{u} vs {v}");
        }
    }

    #[test]
    fn exact_for_spd_tridiagonal() {
        let a = lap1d(16);
        let p = Ilu0::new(&a).unwrap();
        let b = vec![1.0; 16];
        let mut z = vec![0.0; 16];
        p.apply(&b, &mut z);
        let az = a.matvec(&z);
        for (u, v) in az.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_missing_diagonal() {
        let a = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 1.0)]);
        assert!(Ilu0::new(&a).is_err());
    }

    #[test]
    fn symbolic_refactor_matches_fresh_build() {
        let a = nonsym(24);
        let sym = IluSymbolic::new(a.sparsity()).unwrap();
        for shift in [0.0, 0.125, 1.5] {
            let b = a.add_diag(shift);
            let fresh = Ilu0::new(&b).unwrap();
            let reused = sym.refactor(&b).unwrap();
            let r: Vec<f64> = (0..24).map(|i| (i as f64).sin()).collect();
            let (mut z1, mut z2) = (vec![0.0; 24], vec![0.0; 24]);
            fresh.apply(&r, &mut z1);
            reused.apply(&r, &mut z2);
            for (u, v) in z1.iter().zip(&z2) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }
}
