//! ICC(0): incomplete Cholesky with zero fill-in.
//!
//! Defined for SPD matrices; the paper nevertheless reports ICC columns for
//! nonsymmetric problems (PETSc applies it to a symmetric splitting), so for
//! nonsymmetric input we factor the symmetric part ½(A+Aᵀ), with a diagonal
//! shift escalated until the incomplete factorization succeeds — the same
//! `shift` strategy PETSc's `icc` uses. See DESIGN.md §Substitutions.

use super::Preconditioner;
use crate::la::Csr;
use anyhow::{bail, Result};

/// ICC(0) factor L (lower triangular, same pattern as tril(A)); apply solves
/// L Lᵀ z = r.
#[derive(Debug, Clone)]
pub struct Icc0 {
    /// Lower-triangular factor in CSR (rows sorted, diagonal last in row).
    l: Csr,
    diag_pos: Vec<usize>,
}

impl Icc0 {
    pub fn new(a: &Csr) -> Result<Icc0> {
        let sym = if a.asymmetry() > 1e-12 { a.symmetric_part() } else { a.clone() };
        let mut shift = 0.0;
        for attempt in 0..8 {
            match Self::factor(&sym, shift) {
                Ok(icc) => return Ok(icc),
                Err(_) if attempt < 7 => {
                    // escalate the Manteuffel shift
                    let base = sym.diag().iter().fold(0.0f64, |m, d| m.max(d.abs()));
                    shift = if shift == 0.0 { 1e-3 * base } else { shift * 4.0 };
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!()
    }

    fn factor(a: &Csr, shift: f64) -> Result<Icc0> {
        let n = a.nrows();
        // Extract the lower triangle (including diagonal, shifted).
        let mut trips = Vec::new();
        for i in 0..n {
            let (cols, vals) = a.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                if c < i {
                    trips.push((i, c, v));
                } else if c == i {
                    trips.push((i, c, v + shift));
                }
            }
        }
        let mut l = Csr::from_triplets(n, n, &trips);
        let mut diag_pos = vec![usize::MAX; n];
        for i in 0..n {
            for k in l.row_ptr[i]..l.row_ptr[i + 1] {
                if l.col_idx[k] == i {
                    diag_pos[i] = k;
                }
            }
            if diag_pos[i] == usize::MAX {
                bail!("ICC0: structurally zero diagonal at row {i}");
            }
        }
        // Row-oriented incomplete Cholesky restricted to the pattern:
        // for each row i: L[i,j] = (A[i,j] - Σ_k<j L[i,k] L[j,k]) / L[j,j],
        // L[i,i] = sqrt(A[i,i] - Σ_k<i L[i,k]²).
        for i in 0..n {
            let (start, end) = (l.row_ptr[i], l.row_ptr[i + 1]);
            for kk in start..end {
                let j = l.col_idx[kk];
                // dot of row i and row j over columns < j (pattern-restricted)
                let mut s = l.vals[kk];
                {
                    let (mut p, mut q) = (start, l.row_ptr[j]);
                    let (pend, qend) = (kk, diag_pos[j]);
                    while p < pend && q < qend {
                        let (ci, cj) = (l.col_idx[p], l.col_idx[q]);
                        if ci == cj {
                            s -= l.vals[p] * l.vals[q];
                            p += 1;
                            q += 1;
                        } else if ci < cj {
                            p += 1;
                        } else {
                            q += 1;
                        }
                    }
                }
                if j == i {
                    if s <= 0.0 {
                        bail!("ICC0: negative pivot at row {i} (s={s})");
                    }
                    l.vals[kk] = s.sqrt();
                } else {
                    let ljj = l.vals[diag_pos[j]];
                    l.vals[kk] = s / ljj;
                }
            }
        }
        Ok(Icc0 { l, diag_pos })
    }
}

impl Preconditioner for Icc0 {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = r.len();
        // Forward solve L y = r.
        for i in 0..n {
            let start = self.l.row_ptr[i];
            let dp = self.diag_pos[i];
            let mut s = r[i];
            for k in start..dp {
                s -= self.l.vals[k] * z[self.l.col_idx[k]];
            }
            z[i] = s / self.l.vals[dp];
        }
        // Backward solve Lᵀ z = y (column sweep on L).
        for i in (0..n).rev() {
            let dp = self.diag_pos[i];
            z[i] /= self.l.vals[dp];
            let start = self.l.row_ptr[i];
            let zi = z[i];
            for k in start..dp {
                z[self.l.col_idx[k]] -= self.l.vals[k] * zi;
            }
        }
    }

    fn name(&self) -> &'static str {
        "icc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn exact_for_spd_tridiagonal() {
        // Tridiagonal SPD ⇒ no fill ⇒ IC(0) is the exact Cholesky factor.
        let a = lap1d(24);
        let p = Icc0::new(&a).unwrap();
        let xtrue: Vec<f64> = (0..24).map(|i| (i as f64 * 0.3).sin()).collect();
        let b = a.matvec(&xtrue);
        let mut z = vec![0.0; 24];
        p.apply(&b, &mut z);
        for (u, v) in z.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn handles_nonsymmetric_input_via_symmetric_part() {
        let a = nonsym(32);
        let p = Icc0::new(&a).unwrap();
        let r = vec![1.0; 32];
        let mut z = vec![0.0; 32];
        p.apply(&r, &mut z);
        assert!(z.iter().all(|v| v.is_finite()));
        assert!(crate::la::norm2(&z) > 0.0);
    }

    #[test]
    fn symmetric_apply_is_symmetric_operator() {
        // M⁻¹ = L⁻ᵀL⁻¹ is symmetric: ⟨M⁻¹u, v⟩ == ⟨u, M⁻¹v⟩.
        let a = lap1d(16);
        let p = Icc0::new(&a).unwrap();
        let u: Vec<f64> = (0..16).map(|i| (i as f64).cos()).collect();
        let v: Vec<f64> = (0..16).map(|i| (i as f64 * 0.5).sin()).collect();
        let (mut mu, mut mv) = (vec![0.0; 16], vec![0.0; 16]);
        p.apply(&u, &mut mu);
        p.apply(&v, &mut mv);
        let lhs = crate::la::dot(&mu, &v);
        let rhs = crate::la::dot(&u, &mv);
        assert!((lhs - rhs).abs() < 1e-10 * lhs.abs().max(1.0));
    }
}
