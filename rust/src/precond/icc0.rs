//! ICC(0): incomplete Cholesky with zero fill-in.
//!
//! Defined for SPD matrices; the paper nevertheless reports ICC columns for
//! nonsymmetric problems (PETSc applies it to a symmetric splitting), so for
//! nonsymmetric input we factor the symmetric part ½(A+Aᵀ), with a diagonal
//! shift escalated until the incomplete factorization succeeds — the same
//! `shift` strategy PETSc's `icc` uses. See DESIGN.md §Substitutions.
//!
//! The factorization splits into [`IccSymbolic`] (the lower-triangle pattern,
//! its diagonal positions, and a map from lower entries back into A's value
//! array — all functions of the shared [`Sparsity`]) and a numeric phase that
//! stamps values and runs the IC(0) sweep per system.

use super::Preconditioner;
use crate::la::{Csr, Sparsity};
use anyhow::{bail, Result};
use std::sync::Arc;

/// ICC(0) factor L (lower triangular, same pattern as tril(A)); apply solves
/// L Lᵀ z = r.
#[derive(Debug, Clone)]
pub struct Icc0 {
    /// Lower-triangular factor in CSR (rows sorted, diagonal last in row).
    l: Csr,
    diag_pos: Vec<usize>,
}

/// Structural half of ICC(0), reusable across every system with the same
/// sparsity (for the symmetric fast path; value-asymmetric systems fall back
/// to factoring ½(A+Aᵀ) from scratch).
#[derive(Debug, Clone)]
pub struct IccSymbolic {
    sparsity: Arc<Sparsity>,
    /// Pattern of tril(A) including the diagonal.
    lower: Arc<Sparsity>,
    /// Diagonal position within each row of `lower`.
    diag_pos: Vec<usize>,
    /// For each `lower` entry, its position in A's value array.
    src: Vec<usize>,
}

impl IccSymbolic {
    pub fn new(sparsity: &Arc<Sparsity>) -> Result<IccSymbolic> {
        let n = sparsity.nrows();
        let mut row_ptr = vec![0usize; n + 1];
        let mut col_idx = Vec::new();
        let mut src = Vec::new();
        for i in 0..n {
            let mut has_diag = false;
            for k in sparsity.row_range(i) {
                let c = sparsity.col_idx[k];
                if c > i {
                    break;
                }
                col_idx.push(c);
                src.push(k);
                if c == i {
                    has_diag = true;
                }
            }
            if !has_diag {
                bail!("ICC0: structurally zero diagonal at row {i}");
            }
            row_ptr[i + 1] = col_idx.len();
        }
        let lower = Arc::new(Sparsity::from_parts(n, n, row_ptr, col_idx));
        let diag_pos: Vec<usize> = (0..n).map(|i| lower.diag_pos(i).unwrap()).collect();
        Ok(IccSymbolic { sparsity: sparsity.clone(), lower, diag_pos, src })
    }

    /// Numeric factorization of `a` on the precomputed structure, with the
    /// same shift-escalation and symmetric-part fallback as a fresh build.
    pub fn refactor(&self, a: &Csr) -> Result<Icc0> {
        if a.asymmetry() > 1e-12 {
            let sym = a.symmetric_part();
            let symbolic = IccSymbolic::new(sym.sparsity())?;
            return symbolic.attempt_loop(sym.values());
        }
        debug_assert!(
            Arc::ptr_eq(&self.sparsity, a.sparsity()) || *self.sparsity == **a.sparsity(),
            "ICC0 refactor: sparsity mismatch"
        );
        self.attempt_loop(a.values())
    }

    fn attempt_loop(&self, avals: &[f64]) -> Result<Icc0> {
        let mut shift = 0.0;
        for attempt in 0..8 {
            match self.factor_values(avals, shift) {
                Ok(icc) => return Ok(icc),
                Err(_) if attempt < 7 => {
                    // escalate the Manteuffel shift
                    let base =
                        self.diag_pos.iter().fold(0.0f64, |m, &dp| m.max(avals[self.src[dp]].abs()));
                    shift = if shift == 0.0 { 1e-3 * base } else { shift * 4.0 };
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!()
    }

    fn factor_values(&self, avals: &[f64], shift: f64) -> Result<Icc0> {
        let n = self.lower.nrows();
        // Stamp tril(A) values (diagonal shifted) onto the lower pattern.
        let mut vals: Vec<f64> = self.src.iter().map(|&k| avals[k]).collect();
        for &dp in &self.diag_pos {
            vals[dp] += shift;
        }
        let row_ptr = &self.lower.row_ptr;
        let col_idx = &self.lower.col_idx;
        let diag_pos = &self.diag_pos;
        // Row-oriented incomplete Cholesky restricted to the pattern:
        // for each row i: L[i,j] = (A[i,j] - Σ_k<j L[i,k] L[j,k]) / L[j,j],
        // L[i,i] = sqrt(A[i,i] - Σ_k<i L[i,k]²).
        for i in 0..n {
            let (start, end) = (row_ptr[i], row_ptr[i + 1]);
            for kk in start..end {
                let j = col_idx[kk];
                // dot of row i and row j over columns < j (pattern-restricted)
                let mut s = vals[kk];
                {
                    let (mut p, mut q) = (start, row_ptr[j]);
                    let (pend, qend) = (kk, diag_pos[j]);
                    while p < pend && q < qend {
                        let (ci, cj) = (col_idx[p], col_idx[q]);
                        if ci == cj {
                            s -= vals[p] * vals[q];
                            p += 1;
                            q += 1;
                        } else if ci < cj {
                            p += 1;
                        } else {
                            q += 1;
                        }
                    }
                }
                if j == i {
                    if s <= 0.0 {
                        bail!("ICC0: negative pivot at row {i} (s={s})");
                    }
                    vals[kk] = s.sqrt();
                } else {
                    let ljj = vals[diag_pos[j]];
                    vals[kk] = s / ljj;
                }
            }
        }
        let l = Csr::with_values(self.lower.clone(), vals)?;
        Ok(Icc0 { l, diag_pos: self.diag_pos.clone() })
    }
}

impl Icc0 {
    pub fn new(a: &Csr) -> Result<Icc0> {
        IccSymbolic::new(a.sparsity())?.refactor(a)
    }
}

impl Preconditioner for Icc0 {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = r.len();
        let row_ptr = self.l.row_offsets();
        let col_idx = self.l.col_indices();
        let vals = self.l.values();
        // Forward solve L y = r.
        for i in 0..n {
            let start = row_ptr[i];
            let dp = self.diag_pos[i];
            let mut s = r[i];
            for k in start..dp {
                s -= vals[k] * z[col_idx[k]];
            }
            z[i] = s / vals[dp];
        }
        // Backward solve Lᵀ z = y (column sweep on L).
        for i in (0..n).rev() {
            let dp = self.diag_pos[i];
            z[i] /= vals[dp];
            let start = row_ptr[i];
            let zi = z[i];
            for k in start..dp {
                z[col_idx[k]] -= vals[k] * zi;
            }
        }
    }

    fn name(&self) -> &'static str {
        "icc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::{lap1d, nonsym};

    #[test]
    fn exact_for_spd_tridiagonal() {
        // Tridiagonal SPD ⇒ no fill ⇒ IC(0) is the exact Cholesky factor.
        let a = lap1d(24);
        let p = Icc0::new(&a).unwrap();
        let xtrue: Vec<f64> = (0..24).map(|i| (i as f64 * 0.3).sin()).collect();
        let b = a.matvec(&xtrue);
        let mut z = vec![0.0; 24];
        p.apply(&b, &mut z);
        for (u, v) in z.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn handles_nonsymmetric_input_via_symmetric_part() {
        let a = nonsym(32);
        let p = Icc0::new(&a).unwrap();
        let r = vec![1.0; 32];
        let mut z = vec![0.0; 32];
        p.apply(&r, &mut z);
        assert!(z.iter().all(|v| v.is_finite()));
        assert!(crate::la::norm2(&z) > 0.0);
    }

    #[test]
    fn symmetric_apply_is_symmetric_operator() {
        // M⁻¹ = L⁻ᵀL⁻¹ is symmetric: ⟨M⁻¹u, v⟩ == ⟨u, M⁻¹v⟩.
        let a = lap1d(16);
        let p = Icc0::new(&a).unwrap();
        let u: Vec<f64> = (0..16).map(|i| (i as f64).cos()).collect();
        let v: Vec<f64> = (0..16).map(|i| (i as f64 * 0.5).sin()).collect();
        let (mut mu, mut mv) = (vec![0.0; 16], vec![0.0; 16]);
        p.apply(&u, &mut mu);
        p.apply(&v, &mut mv);
        let lhs = crate::la::dot(&mu, &v);
        let rhs = crate::la::dot(&u, &mv);
        assert!((lhs - rhs).abs() < 1e-10 * lhs.abs().max(1.0));
    }

    #[test]
    fn symbolic_refactor_matches_fresh_build() {
        let a = lap1d(20);
        let sym = IccSymbolic::new(a.sparsity()).unwrap();
        for shift in [0.0, 0.25, 2.0] {
            let b = a.add_diag(shift);
            let fresh = Icc0::new(&b).unwrap();
            let reused = sym.refactor(&b).unwrap();
            let r: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();
            let (mut z1, mut z2) = (vec![0.0; 20], vec![0.0; 20]);
            fresh.apply(&r, &mut z1);
            reused.apply(&r, &mut z2);
            for (u, v) in z1.iter().zip(&z2) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }
}
