//! Diagonal (Jacobi) preconditioner.

use super::Preconditioner;
use crate::la::Csr;
use anyhow::{bail, Result};

/// z = D⁻¹ r with D = diag(A).
#[derive(Debug, Clone)]
pub struct Jacobi {
    inv_diag: Vec<f64>,
}

impl Jacobi {
    pub fn new(a: &Csr) -> Result<Jacobi> {
        let d = a.diag();
        let mut inv_diag = Vec::with_capacity(d.len());
        for (i, &di) in d.iter().enumerate() {
            if di == 0.0 {
                bail!("Jacobi: zero diagonal at row {i}");
            }
            inv_diag.push(1.0 / di);
        }
        Ok(Jacobi { inv_diag })
    }
}

impl Preconditioner for Jacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for i in 0..r.len() {
            z[i] = r[i] * self.inv_diag[i];
        }
    }

    fn name(&self) -> &'static str {
        "jacobi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::testutil::lap1d;

    #[test]
    fn divides_by_diagonal() {
        let a = lap1d(4);
        let p = Jacobi::new(&a).unwrap();
        let mut z = vec![0.0; 4];
        p.apply(&[2.0, 4.0, 6.0, 8.0], &mut z);
        assert_eq!(z, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rejects_zero_diagonal() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0)]);
        assert!(Jacobi::new(&a).is_err());
    }
}
