//! Darcy flow: −∇·(K(x,y)∇h) = f on the unit square, Dirichlet h = 0.
//!
//! K is a lognormal permeability field exp(σ·GRF) (the standard FNO-Darcy
//! construction; the paper samples K via GRF and sorts by its parameters).
//! Discretized by a 5-point finite-volume scheme with harmonic face
//! averaging, f ≡ 1.

use super::grf::{self, GrfConfig};
use super::grid::Grid;
use super::ProblemFamily;
use crate::la::{Csr, Sparsity};
use crate::solver::LinearSystem;
use crate::util::prng::Rng;
use crate::util::shared::SharedOnce;
use anyhow::Result;

/// How the GRF is mapped to a permeability field.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KMap {
    /// K = exp(σ·GRF) — lognormal, σ controls the contrast.
    LogNormal(f64),
    /// K = hi where GRF ≥ 0, lo elsewhere — the piecewise-constant
    /// two-phase medium of the standard FNO Darcy benchmark (Li et al.
    /// 2020), which the paper's dataset follows. High contrast ⇒ slow
    /// GMRES ⇒ the regime the paper benchmarks.
    TwoPhase { lo: f64, hi: f64 },
}

/// Darcy problem generator.
#[derive(Debug, Clone)]
pub struct DarcyFamily {
    grid: Grid,
    /// GRF → permeability map.
    pub kmap: KMap,
    pub grf: GrfConfig,
    /// Side of the coarse parameter grid used as the sort key.
    pub param_side: usize,
    /// The 5-point stencil pattern, built once per (family, grid) and shared
    /// by every sampled system — samples only stamp values onto it.
    pattern: SharedOnce<Sparsity>,
}

impl DarcyFamily {
    pub fn new(interior_side: usize) -> DarcyFamily {
        DarcyFamily {
            grid: Grid::new(interior_side),
            // High-contrast two-phase medium (contrast 1.2·10³): puts the
            // GMRES baseline into the paper's iteration regime (thousands of
            // iterations even preconditioned; the unpreconditioned baseline
            // frequently hits the 10⁴ cap, exactly as the paper's Fig. 13
            // reports) while SKR still converges.
            kmap: KMap::TwoPhase { lo: 1e-2, hi: 12.0 },
            grf: GrfConfig::default(),
            param_side: 16,
            pattern: SharedOnce::new(),
        }
    }

    pub fn with_unknowns(unknowns: usize) -> DarcyFamily {
        DarcyFamily::new(Grid::for_unknowns(unknowns).n)
    }

    /// Sample the permeability field on the (n+2)² node grid (including
    /// boundary ring) so faces always have two owners.
    fn sample_k(&self, rng: &mut Rng) -> (Vec<f64>, usize) {
        let side = self.grid.n + 2;
        let p2 = grf::next_pow2(side);
        let raw = grf::sample(p2, &self.grf, rng);
        let field = grf::resample(&raw, p2, side);
        let k: Vec<f64> = match self.kmap {
            KMap::LogNormal(sigma) => field.iter().map(|v| (sigma * v).exp()).collect(),
            KMap::TwoPhase { lo, hi } => {
                field.iter().map(|&v| if v >= 0.0 { hi } else { lo }).collect()
            }
        };
        (k, side)
    }

    /// Mirror of the stencil loop in [`ProblemFamily::sample`], positions
    /// only: one (row, col) pair per nonzero.
    fn build_pattern(&self) -> Sparsity {
        let n = self.grid.n;
        let mut pairs = Vec::with_capacity(5 * n * n);
        for i in 0..n {
            for j in 0..n {
                let row = self.grid.idx(i, j);
                pairs.push((row, row));
                if i > 0 {
                    pairs.push((row, self.grid.idx(i - 1, j)));
                }
                if i + 1 < n {
                    pairs.push((row, self.grid.idx(i + 1, j)));
                }
                if j > 0 {
                    pairs.push((row, self.grid.idx(i, j - 1)));
                }
                if j + 1 < n {
                    pairs.push((row, self.grid.idx(i, j + 1)));
                }
            }
        }
        Sparsity::from_pattern(n * n, n * n, &pairs)
    }
}

impl ProblemFamily for DarcyFamily {
    fn name(&self) -> &'static str {
        "darcy"
    }

    fn num_unknowns(&self) -> usize {
        self.grid.size()
    }

    fn sample(&self, id: usize, rng: &mut Rng) -> Result<LinearSystem> {
        let n = self.grid.n;
        let h2 = self.grid.h * self.grid.h;
        let (k, side) = self.sample_k(rng);
        let node = |i: usize, j: usize| k[(i + 1) * side + (j + 1)]; // interior (i,j) → node grid
        let harm = |a: f64, b: f64| 2.0 * a * b / (a + b);

        // The stencil has no duplicate entries, so stamping values onto the
        // shared pattern is bit-identical to a from_triplets assembly.
        let sp = self.pattern.get_or_init(|| self.build_pattern());
        let mut vals = vec![0.0; sp.nnz()];
        let mut b = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let row = self.grid.idx(i, j);
                let kc = node(i, j);
                // Face transmissibilities to the four neighbours (boundary
                // neighbours use the boundary-ring K value; Dirichlet h=0
                // contributes nothing to b).
                let tn = harm(kc, k[i * side + (j + 1)]); // i-1 side
                let ts = harm(kc, k[(i + 2) * side + (j + 1)]);
                let tw = harm(kc, k[(i + 1) * side + j]);
                let te = harm(kc, k[(i + 1) * side + (j + 2)]);
                let diag = (tn + ts + tw + te) / h2;
                vals[sp.pos(row, row).unwrap()] = diag;
                if i > 0 {
                    vals[sp.pos(row, self.grid.idx(i - 1, j)).unwrap()] = -tn / h2;
                }
                if i + 1 < n {
                    vals[sp.pos(row, self.grid.idx(i + 1, j)).unwrap()] = -ts / h2;
                }
                if j > 0 {
                    vals[sp.pos(row, self.grid.idx(i, j - 1)).unwrap()] = -tw / h2;
                }
                if j + 1 < n {
                    vals[sp.pos(row, self.grid.idx(i, j + 1)).unwrap()] = -te / h2;
                }
                b[row] = 1.0; // f ≡ 1
            }
        }
        let a = Csr::with_values(sp, vals)?;
        // Sort key: the coarse log-K field (the GRF parameters).
        let coarse = grf::resample(
            &k.iter().map(|v| v.ln()).collect::<Vec<_>>(),
            side,
            self.param_side.min(side),
        );
        Ok(LinearSystem { id, a, b, params: coarse })
    }

    fn input_field(&self, sys: &LinearSystem) -> Vec<f64> {
        sys.params.clone()
    }

    fn sample_params(&self, _id: usize, rng: &mut Rng) -> Result<Vec<f64>> {
        // Mirrors sample(): the GRF draw is the only RNG consumption.
        let (k, side) = self.sample_k(rng);
        Ok(grf::resample(
            &k.iter().map(|v| v.ln()).collect::<Vec<_>>(),
            side,
            self.param_side.min(side),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::Identity;
    use crate::solver::{gmres, SolverConfig};

    #[test]
    fn constant_k_reduces_to_poisson_stencil() {
        let mut fam = DarcyFamily::new(4);
        fam.kmap = KMap::LogNormal(0.0); // K ≡ 1
        let mut rng = Rng::new(1);
        let sys = fam.sample(0, &mut rng).unwrap();
        let h2 = fam.grid.h * fam.grid.h;
        // Interior point (1,1) has the classic 5-point row: 4/h², −1/h²×4.
        let row = fam.grid.idx(1, 1);
        assert!((sys.a.get(row, row) - 4.0 / h2).abs() < 1e-9);
        assert!((sys.a.get(row, fam.grid.idx(0, 1)) + 1.0 / h2).abs() < 1e-9);
    }

    #[test]
    fn matrix_is_spd_like_and_solvable() {
        let fam = DarcyFamily::new(12);
        let mut rng = Rng::new(2);
        let sys = fam.sample(0, &mut rng).unwrap();
        assert!(sys.a.asymmetry() < 1e-12, "FVM harmonic scheme is symmetric");
        let mut x = vec![0.0; sys.b.len()];
        let s = gmres(&sys.a, &sys.b, &mut x, &Identity, &SolverConfig::default().with_tol(1e-10));
        assert!(s.converged());
        // Pressure is positive inside (f = 1, zero Dirichlet).
        assert!(x.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn params_track_field_similarity() {
        // Two samples from the same stream are identical; different streams differ.
        let fam = DarcyFamily::new(8);
        let s1 = fam.sample(0, &mut Rng::new(5)).unwrap();
        let s2 = fam.sample(0, &mut Rng::new(5)).unwrap();
        let s3 = fam.sample(1, &mut Rng::new(6)).unwrap();
        assert_eq!(s1.params, s2.params);
        assert_ne!(s1.params, s3.params);
        // Param grid is min(param_side, n+2)² values.
        let ps = fam.param_side.min(fam.grid.n + 2);
        assert_eq!(s1.params.len(), ps * ps);
    }

    #[test]
    fn samples_share_one_sparsity() {
        let fam = DarcyFamily::new(8);
        let s1 = fam.sample(0, &mut Rng::new(1)).unwrap();
        let s2 = fam.sample(1, &mut Rng::new(2)).unwrap();
        assert!(std::sync::Arc::ptr_eq(s1.a.sparsity(), s2.a.sparsity()));
        assert_ne!(s1.a.values(), s2.a.values());
    }
}
