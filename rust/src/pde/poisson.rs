//! Poisson equation ∇²u = f on [-1,1]² with Dirichlet boundary data; f and
//! the four boundary traces are truncated Chebyshev series whose
//! coefficients are the sort key (paper Appendix D.2.3).

use super::chebyshev::{Cheb1, Cheb2};
use super::grid::Grid;
use super::ProblemFamily;
use crate::la::Csr;
use crate::solver::LinearSystem;
use crate::util::prng::Rng;
use crate::util::shared::SharedOnce;
use anyhow::Result;

/// Poisson problem generator.
#[derive(Debug, Clone)]
pub struct PoissonFamily {
    grid: Grid,
    /// Chebyshev truncation degree for the five series.
    pub degree: usize,
    /// The operator is parameter-independent: assembled once, then cloned —
    /// every sampled system shares one `Arc<Sparsity>` (the value vector is
    /// cloned, keeping `Csr`'s value-ownership semantics).
    laplacian_cache: SharedOnce<Csr>,
}

impl PoissonFamily {
    pub fn new(interior_side: usize) -> PoissonFamily {
        PoissonFamily {
            grid: Grid::new(interior_side),
            degree: 8,
            laplacian_cache: SharedOnce::new(),
        }
    }

    pub fn with_unknowns(unknowns: usize) -> PoissonFamily {
        PoissonFamily::new(Grid::for_unknowns(unknowns).n)
    }

    /// The (constant-in-parameters) 5-point Laplacian.
    fn laplacian(&self) -> Csr {
        (*self.laplacian_cache.get_or_init(|| self.build_laplacian())).clone()
    }

    fn build_laplacian(&self) -> Csr {
        let n = self.grid.n;
        let h2 = self.grid.h * self.grid.h * 4.0; // domain [-1,1] ⇒ spacing 2h
        let mut trips = Vec::with_capacity(5 * n * n);
        for i in 0..n {
            for j in 0..n {
                let row = self.grid.idx(i, j);
                trips.push((row, row, -4.0 / h2));
                if i > 0 {
                    trips.push((row, self.grid.idx(i - 1, j), 1.0 / h2));
                }
                if i + 1 < n {
                    trips.push((row, self.grid.idx(i + 1, j), 1.0 / h2));
                }
                if j > 0 {
                    trips.push((row, self.grid.idx(i, j - 1), 1.0 / h2));
                }
                if j + 1 < n {
                    trips.push((row, self.grid.idx(i, j + 1), 1.0 / h2));
                }
            }
        }
        Csr::from_triplets(n * n, n * n, &trips)
    }
}

impl ProblemFamily for PoissonFamily {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn num_unknowns(&self) -> usize {
        self.grid.size()
    }

    fn sample(&self, id: usize, rng: &mut Rng) -> Result<LinearSystem> {
        let n = self.grid.n;
        let h2 = self.grid.h * self.grid.h * 4.0;
        // Five Chebyshev series: four boundary traces + the source f.
        let gb: Vec<Cheb1> = (0..4).map(|_| Cheb1::random(self.degree, rng)).collect();
        let f = Cheb2::random(1, self.degree, rng);

        // Map interior index to [-1,1] coordinates.
        let coord = |t: usize| -1.0 + 2.0 * (t as f64 + 1.0) * self.grid.h;
        let a = self.laplacian();
        let mut b = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let row = self.grid.idx(i, j);
                let (x, y) = (coord(i), coord(j));
                b[row] = f.eval(x, y);
                // Dirichlet lift: subtract g/h² for boundary neighbours.
                if i == 0 {
                    b[row] -= gb[0].eval(y) / h2; // x = −1 edge
                }
                if i == n - 1 {
                    b[row] -= gb[1].eval(y) / h2; // x = +1 edge
                }
                if j == 0 {
                    b[row] -= gb[2].eval(x) / h2; // y = −1 edge
                }
                if j == n - 1 {
                    b[row] -= gb[3].eval(x) / h2; // y = +1 edge
                }
            }
        }
        // Sort key: all five coefficient vectors, concatenated.
        let mut params = Vec::new();
        for g in &gb {
            params.extend_from_slice(&g.coeffs);
        }
        params.extend(f.param_vec());
        Ok(LinearSystem { id, a, b, params })
    }

    fn sample_params(&self, _id: usize, rng: &mut Rng) -> Result<Vec<f64>> {
        let gb: Vec<Cheb1> = (0..4).map(|_| Cheb1::random(self.degree, rng)).collect();
        let f = Cheb2::random(1, self.degree, rng);
        let mut params = Vec::new();
        for g in &gb {
            params.extend_from_slice(&g.coeffs);
        }
        params.extend(f.param_vec());
        Ok(params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::Identity;
    use crate::solver::{gmres, SolverConfig};

    #[test]
    fn matches_manufactured_solution() {
        // u = x² + y² ⇒ ∇²u = 4; boundary handled through the Dirichlet lift
        // (we emulate it by comparing against the interior of the discrete
        // solve of the same stencil with exact boundary data).
        let fam = PoissonFamily::new(24);
        let n = fam.grid.n;
        let h2 = fam.grid.h * fam.grid.h * 4.0;
        let coord = |t: usize| -1.0 + 2.0 * (t as f64 + 1.0) * fam.grid.h;
        let a = fam.laplacian();
        let mut b = vec![0.0; n * n];
        let g = |x: f64, y: f64| x * x + y * y;
        for i in 0..n {
            for j in 0..n {
                let row = fam.grid.idx(i, j);
                b[row] = 4.0;
                let (x, y) = (coord(i), coord(j));
                if i == 0 {
                    b[row] -= g(-1.0, y) / h2;
                }
                if i == n - 1 {
                    b[row] -= g(1.0, y) / h2;
                }
                if j == 0 {
                    b[row] -= g(x, -1.0) / h2;
                }
                if j == n - 1 {
                    b[row] -= g(x, 1.0) / h2;
                }
            }
        }
        let mut x = vec![0.0; n * n];
        let s = gmres(&a, &b, &mut x, &Identity, &SolverConfig::default().with_tol(1e-12).with_max_iters(50_000));
        assert!(s.converged());
        // The 5-point stencil is exact for quadratics.
        for i in 0..n {
            for j in 0..n {
                let (xx, yy) = (coord(i), coord(j));
                assert!(
                    (x[fam.grid.idx(i, j)] - g(xx, yy)).abs() < 1e-7,
                    "({i},{j}): {} vs {}",
                    x[fam.grid.idx(i, j)],
                    g(xx, yy)
                );
            }
        }
    }

    #[test]
    fn params_have_five_series() {
        let fam = PoissonFamily::new(6);
        let sys = fam.sample(0, &mut Rng::new(3)).unwrap();
        // 4 boundary series of deg+1 plus a rank-1 Cheb2 (2·(deg+1)).
        assert_eq!(sys.params.len(), 4 * (fam.degree + 1) + 2 * (fam.degree + 1));
    }

    #[test]
    fn matrix_constant_across_samples() {
        let fam = PoissonFamily::new(6);
        let s1 = fam.sample(0, &mut Rng::new(1)).unwrap();
        let s2 = fam.sample(1, &mut Rng::new(2)).unwrap();
        assert_eq!(s1.a, s2.a);
        assert_ne!(s1.b, s2.b);
        // The cached operator hands every sample the same Arc<Sparsity>.
        assert!(std::sync::Arc::ptr_eq(s1.a.sparsity(), s2.a.sparsity()));
    }
}
