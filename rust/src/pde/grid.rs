//! Structured-grid helpers for the FDM/FVM problem families: interior-point
//! indexing on the unit square with Dirichlet boundaries.

/// An n×n interior grid on the unit square (boundary nodes eliminated).
#[derive(Debug, Clone, Copy)]
pub struct Grid {
    /// Interior points per direction.
    pub n: usize,
    /// Mesh spacing h = 1 / (n + 1).
    pub h: f64,
}

impl Grid {
    pub fn new(n: usize) -> Grid {
        Grid { n, h: 1.0 / (n as f64 + 1.0) }
    }

    /// Total unknowns.
    pub fn size(&self) -> usize {
        self.n * self.n
    }

    /// Row-major linear index of interior point (i, j), 0-based.
    #[inline]
    pub fn idx(&self, i: usize, j: usize) -> usize {
        i * self.n + j
    }

    /// Physical coordinates of interior point (i, j) in (0,1)².
    #[inline]
    pub fn xy(&self, i: usize, j: usize) -> (f64, f64) {
        ((i as f64 + 1.0) * self.h, (j as f64 + 1.0) * self.h)
    }

    /// Choose the interior side length whose unknown count is closest to
    /// `target` (the paper reports matrix sizes like 2500, 6400, 10000 —
    /// i.e. 50², 80², 100²).
    pub fn for_unknowns(target: usize) -> Grid {
        let side = (target as f64).sqrt().round().max(2.0) as usize;
        Grid::new(side)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let g = Grid::new(5);
        assert_eq!(g.size(), 25);
        assert_eq!(g.idx(0, 0), 0);
        assert_eq!(g.idx(4, 4), 24);
        let (x, y) = g.xy(0, 0);
        assert!((x - g.h).abs() < 1e-15 && (y - g.h).abs() < 1e-15);
        let (x, y) = g.xy(4, 4);
        assert!((x - 5.0 * g.h).abs() < 1e-15 && (y - 5.0 * g.h).abs() < 1e-15);
    }

    #[test]
    fn for_unknowns_hits_paper_sizes() {
        assert_eq!(Grid::for_unknowns(2500).size(), 2500);
        assert_eq!(Grid::for_unknowns(6400).size(), 6400);
        assert_eq!(Grid::for_unknowns(10000).size(), 10000);
    }
}
