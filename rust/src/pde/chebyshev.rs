//! Truncated Chebyshev-polynomial random fields — the paper's parameter
//! source for the Poisson family (boundary data and right-hand side are
//! generated from truncated Chebyshev series; their coefficients form the
//! sort key).

use crate::util::prng::Rng;

/// A 1-D truncated Chebyshev series on [-1, 1].
#[derive(Debug, Clone)]
pub struct Cheb1 {
    pub coeffs: Vec<f64>,
}

impl Cheb1 {
    /// Random series with `deg+1` coefficients decaying like 1/(j+1).
    pub fn random(deg: usize, rng: &mut Rng) -> Cheb1 {
        let coeffs = (0..=deg).map(|j| rng.normal() / (j as f64 + 1.0)).collect();
        Cheb1 { coeffs }
    }

    /// Evaluate at x ∈ [-1, 1] by Clenshaw recurrence.
    pub fn eval(&self, x: f64) -> f64 {
        let mut b1 = 0.0;
        let mut b2 = 0.0;
        for &c in self.coeffs.iter().rev() {
            let b0 = 2.0 * x * b1 - b2 + c;
            b2 = b1;
            b1 = b0;
        }
        // Clenshaw for Chebyshev: f = b1 - x*b2 ... using T_n convention:
        b1 - x * b2
    }
}

/// A separable 2-D field f(x,y) = Σᵢ gᵢ(x)·hᵢ(y) from a few random 1-D series.
#[derive(Debug, Clone)]
pub struct Cheb2 {
    pub gx: Vec<Cheb1>,
    pub hy: Vec<Cheb1>,
}

impl Cheb2 {
    pub fn random(rank: usize, deg: usize, rng: &mut Rng) -> Cheb2 {
        Cheb2 {
            gx: (0..rank).map(|_| Cheb1::random(deg, rng)).collect(),
            hy: (0..rank).map(|_| Cheb1::random(deg, rng)).collect(),
        }
    }

    /// Evaluate at (x, y) ∈ [-1,1]².
    pub fn eval(&self, x: f64, y: f64) -> f64 {
        self.gx.iter().zip(&self.hy).map(|(g, h)| g.eval(x) * h.eval(y)).sum()
    }

    /// Flattened coefficient vector (the sorting key).
    pub fn param_vec(&self) -> Vec<f64> {
        let mut v = Vec::new();
        for g in &self.gx {
            v.extend_from_slice(&g.coeffs);
        }
        for h in &self.hy {
            v.extend_from_slice(&h.coeffs);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clenshaw_matches_direct_for_low_orders() {
        // T0=1, T1=x, T2=2x²−1.
        let c = Cheb1 { coeffs: vec![1.0, 2.0, 3.0] };
        for &x in &[-1.0, -0.3, 0.0, 0.5, 1.0] {
            let direct = 1.0 + 2.0 * x + 3.0 * (2.0 * x * x - 1.0);
            assert!((c.eval(x) - direct).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn param_vec_lengths() {
        let mut rng = Rng::new(4);
        let f = Cheb2::random(3, 4, &mut rng);
        assert_eq!(f.param_vec().len(), 2 * 3 * 5);
    }

    #[test]
    fn separable_eval() {
        let g = Cheb1 { coeffs: vec![0.0, 1.0] }; // g(x) = x
        let h = Cheb1 { coeffs: vec![0.0, 1.0] };
        let f = Cheb2 { gx: vec![g], hy: vec![h] };
        assert!((f.eval(0.5, -0.25) + 0.125).abs() < 1e-14);
    }
}
