//! Thermal steady state: ΔT = 0 on an irregular annular-sector domain (P1
//! FEM, paper Appendix D.2.2). The inner ("left") and outer ("right")
//! boundary temperatures are uniform random values in [−100, 0] and
//! [0, 100]; those two values are the sort key.

use super::fem::{assemble_laplace_cached, Mesh};
use super::ProblemFamily;
use crate::la::Csr;
use crate::solver::LinearSystem;
use crate::util::prng::Rng;
use crate::util::shared::SharedOnce;
use anyhow::Result;

/// Thermal problem generator (FEM on a fixed irregular mesh; the boundary
/// data varies per sample).
pub struct ThermalFamily {
    mesh: Mesh,
    unknowns: usize,
    /// The stiffness matrix depends only on the mesh: assembled once, then
    /// every sample clones it (one shared `Arc<Sparsity>`) and rebuilds only
    /// the Dirichlet-lift load vector.
    stiffness: SharedOnce<Csr>,
}

impl ThermalFamily {
    pub fn new(nr: usize, nth: usize) -> ThermalFamily {
        // Wavy outer boundary + radial grading: thin boundary-layer elements
        // give the stiffness matrix the conditioning of the paper's
        // irregular thermal mesh (GMRES baseline in the thousands of
        // iterations unpreconditioned).
        let mesh = Mesh::annular_sector_graded(nr, nth, 0.3, 2.5);
        let unknowns = mesh.num_interior();
        ThermalFamily { mesh, unknowns, stiffness: SharedOnce::new() }
    }

    /// Pick (nr, nth) with interior count close to `unknowns`
    /// (interior = (nr − 2) · nth with our tagging).
    pub fn with_unknowns(unknowns: usize) -> ThermalFamily {
        // Aspect ratio ~1:3 (radial thinner than angular), matching an
        // annulus geometry.
        let nr = ((unknowns as f64 / 3.0).sqrt().round() as usize + 2).max(4);
        let nth = (unknowns / (nr - 2)).max(4);
        ThermalFamily::new(nr, nth)
    }

    pub fn mesh(&self) -> &Mesh {
        &self.mesh
    }
}

impl ProblemFamily for ThermalFamily {
    fn name(&self) -> &'static str {
        "thermal"
    }

    fn num_unknowns(&self) -> usize {
        self.unknowns
    }

    fn field_side(&self) -> usize {
        0 // unstructured
    }

    fn sample(&self, id: usize, rng: &mut Rng) -> Result<LinearSystem> {
        let t_inner = rng.uniform_in(-100.0, 0.0);
        let t_outer = rng.uniform_in(0.0, 100.0);
        let sys = assemble_laplace_cached(
            &self.mesh,
            &move |grp| if grp == 0 { t_inner } else { t_outer },
            Some(&self.stiffness),
        )?;
        Ok(LinearSystem { id, a: sys.a, b: sys.b, params: vec![t_inner, t_outer] })
    }

    fn sample_params(&self, _id: usize, rng: &mut Rng) -> Result<Vec<f64>> {
        let t_inner = rng.uniform_in(-100.0, 0.0);
        let t_outer = rng.uniform_in(0.0, 100.0);
        Ok(vec![t_inner, t_outer])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::Identity;
    use crate::solver::{gmres, SolverConfig};

    #[test]
    fn unknown_count_is_close_to_target() {
        for target in [200usize, 1000] {
            let fam = ThermalFamily::with_unknowns(target);
            let got = fam.num_unknowns();
            assert!(
                (got as f64) > 0.5 * target as f64 && (got as f64) < 2.0 * target as f64,
                "target {target} got {got}"
            );
        }
    }

    #[test]
    fn solution_between_boundary_temperatures() {
        let fam = ThermalFamily::new(8, 24);
        let sys = fam.sample(0, &mut Rng::new(11)).unwrap();
        let (tin, tout) = (sys.params[0], sys.params[1]);
        let mut x = vec![0.0; sys.b.len()];
        let s = gmres(&sys.a, &sys.b, &mut x, &Identity, &SolverConfig::default().with_tol(1e-11));
        assert!(s.converged());
        for &v in &x {
            assert!(v >= tin - 1e-6 && v <= tout + 1e-6, "{v} outside [{tin},{tout}]");
        }
    }

    #[test]
    fn samples_share_one_stiffness_sparsity() {
        let fam = ThermalFamily::new(6, 12);
        let s1 = fam.sample(0, &mut Rng::new(1)).unwrap();
        let s2 = fam.sample(1, &mut Rng::new(2)).unwrap();
        assert!(std::sync::Arc::ptr_eq(s1.a.sparsity(), s2.a.sparsity()));
        assert_eq!(s1.a, s2.a); // stiffness is g-independent
        assert_ne!(s1.b, s2.b); // the lift is not
    }

    #[test]
    fn params_are_two_temperatures() {
        let fam = ThermalFamily::new(6, 12);
        let sys = fam.sample(3, &mut Rng::new(2)).unwrap();
        assert_eq!(sys.params.len(), 2);
        assert!((-100.0..=0.0).contains(&sys.params[0]));
        assert!((0.0..=100.0).contains(&sys.params[1]));
    }
}
