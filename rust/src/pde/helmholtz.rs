//! Helmholtz equation ∇²u + k(x,y)²u = f on the unit square with Dirichlet
//! boundaries; the wavenumber field k is GRF-derived (paper Appendix D.2.4),
//! making the discrete operator indefinite and nonsymmetric-hard for GMRES —
//! the family where the paper reports its largest speedups.

use super::grf::{self, GrfConfig};
use super::grid::Grid;
use super::ProblemFamily;
use crate::la::{Csr, Sparsity};
use crate::solver::LinearSystem;
use crate::util::prng::Rng;
use crate::util::shared::SharedOnce;
use anyhow::Result;

/// Helmholtz problem generator.
#[derive(Debug, Clone)]
pub struct HelmholtzFamily {
    grid: Grid,
    /// Base wavenumber k₀ (higher ⇒ more indefinite ⇒ harder).
    pub k0: f64,
    /// Relative GRF modulation amplitude of k.
    pub amplitude: f64,
    pub grf: GrfConfig,
    /// Side of the coarse parameter grid (sort key).
    pub param_side: usize,
    /// The 5-point stencil pattern, built once per (family, grid) and shared
    /// by every sampled system — samples only stamp values onto it.
    pattern: SharedOnce<Sparsity>,
}

impl HelmholtzFamily {
    pub fn new(interior_side: usize) -> HelmholtzFamily {
        HelmholtzFamily {
            grid: Grid::new(interior_side),
            k0: 12.0,
            amplitude: 0.25,
            grf: GrfConfig::default(),
            param_side: 16,
            pattern: SharedOnce::new(),
        }
    }

    pub fn with_unknowns(unknowns: usize) -> HelmholtzFamily {
        HelmholtzFamily::new(Grid::for_unknowns(unknowns).n)
    }

    /// Mirror of the stencil loop in [`ProblemFamily::sample`], positions
    /// only: one (row, col) pair per nonzero.
    fn build_pattern(&self) -> Sparsity {
        let n = self.grid.n;
        let mut pairs = Vec::with_capacity(5 * n * n);
        for i in 0..n {
            for j in 0..n {
                let row = self.grid.idx(i, j);
                pairs.push((row, row));
                if i > 0 {
                    pairs.push((row, self.grid.idx(i - 1, j)));
                }
                if i + 1 < n {
                    pairs.push((row, self.grid.idx(i + 1, j)));
                }
                if j > 0 {
                    pairs.push((row, self.grid.idx(i, j - 1)));
                }
                if j + 1 < n {
                    pairs.push((row, self.grid.idx(i, j + 1)));
                }
            }
        }
        Sparsity::from_pattern(n * n, n * n, &pairs)
    }
}

impl ProblemFamily for HelmholtzFamily {
    fn name(&self) -> &'static str {
        "helmholtz"
    }

    fn num_unknowns(&self) -> usize {
        self.grid.size()
    }

    fn sample(&self, id: usize, rng: &mut Rng) -> Result<LinearSystem> {
        let n = self.grid.n;
        let h2 = self.grid.h * self.grid.h;
        // k(x,y) = k₀ (1 + a·GRF), sampled on the interior grid.
        let p2 = grf::next_pow2(n);
        let raw = grf::sample(p2, &self.grf, rng);
        let field = grf::resample(&raw, p2, n);
        let kvals: Vec<f64> = field.iter().map(|v| self.k0 * (1.0 + self.amplitude * v)).collect();

        // The stencil has no duplicate entries, so stamping values onto the
        // shared pattern is bit-identical to a from_triplets assembly.
        let sp = self.pattern.get_or_init(|| self.build_pattern());
        let mut vals = vec![0.0; sp.nnz()];
        let mut b = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let row = self.grid.idx(i, j);
                let k2 = kvals[row] * kvals[row];
                vals[sp.pos(row, row).unwrap()] = -4.0 / h2 + k2;
                if i > 0 {
                    vals[sp.pos(row, self.grid.idx(i - 1, j)).unwrap()] = 1.0 / h2;
                }
                if i + 1 < n {
                    vals[sp.pos(row, self.grid.idx(i + 1, j)).unwrap()] = 1.0 / h2;
                }
                if j > 0 {
                    vals[sp.pos(row, self.grid.idx(i, j - 1)).unwrap()] = 1.0 / h2;
                }
                if j + 1 < n {
                    vals[sp.pos(row, self.grid.idx(i, j + 1)).unwrap()] = 1.0 / h2;
                }
                // Point-source forcing: localized Gaussian beam, fixed across
                // samples (the variation lives in k).
                let (x, y) = self.grid.xy(i, j);
                let d2 = (x - 0.5) * (x - 0.5) + (y - 0.5) * (y - 0.5);
                b[row] = (-d2 / 0.01).exp();
            }
        }
        let a = Csr::with_values(sp, vals)?;
        let coarse = grf::resample(&kvals, n, self.param_side.min(n));
        Ok(LinearSystem { id, a, b, params: coarse })
    }

    fn sample_params(&self, _id: usize, rng: &mut Rng) -> Result<Vec<f64>> {
        let n = self.grid.n;
        let p2 = grf::next_pow2(n);
        let raw = grf::sample(p2, &self.grf, rng);
        let field = grf::resample(&raw, p2, n);
        let kvals: Vec<f64> =
            field.iter().map(|v| self.k0 * (1.0 + self.amplitude * v)).collect();
        Ok(grf::resample(&kvals, n, self.param_side.min(n)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::Identity;
    use crate::solver::{gmres, SolverConfig};

    #[test]
    fn operator_is_indefinite_shift_of_laplacian() {
        let fam = HelmholtzFamily::new(10);
        let sys = fam.sample(0, &mut Rng::new(1)).unwrap();
        // Diagonal = −4/h² + k², so every diagonal entry sits strictly above
        // the pure-Laplacian value and below −4/h² + (large multiple of k0)².
        let h2 = fam.grid.h * fam.grid.h;
        let lo = -4.0 / h2;
        let hi = -4.0 / h2 + (8.0 * fam.k0).powi(2);
        for &d in &sys.a.diag() {
            assert!(d > lo && d < hi, "{d} outside ({lo},{hi})");
        }
    }

    #[test]
    fn solvable_but_slower_than_poisson_analogue() {
        let fam = HelmholtzFamily::new(14);
        let sys = fam.sample(0, &mut Rng::new(2)).unwrap();
        let mut x = vec![0.0; sys.b.len()];
        let cfg = SolverConfig::default().with_tol(1e-8).with_max_iters(100_000);
        let s = gmres(&sys.a, &sys.b, &mut x, &Identity, &cfg);
        assert!(s.converged(), "{s:?}");
        assert!(s.iters > 10, "should be nontrivial: {}", s.iters);
    }

    #[test]
    fn params_are_the_wavenumber_field() {
        let fam = HelmholtzFamily::new(20);
        let sys = fam.sample(0, &mut Rng::new(3)).unwrap();
        assert_eq!(sys.params.len(), 16 * 16);
        // All k values near k0.
        for &k in &sys.params {
            assert!(k > 0.0 && k < 2.5 * fam.k0);
        }
    }

    #[test]
    fn samples_share_one_sparsity() {
        let fam = HelmholtzFamily::new(10);
        let s1 = fam.sample(0, &mut Rng::new(1)).unwrap();
        let s2 = fam.sample(1, &mut Rng::new(2)).unwrap();
        assert!(std::sync::Arc::ptr_eq(s1.a.sparsity(), s2.a.sparsity()));
        assert_ne!(s1.a.values(), s2.a.values());
    }
}
