//! Minimal unstructured P1 (linear triangle) finite-element substrate —
//! mesh container, structured triangulation of mapped domains, Laplace
//! stiffness assembly, and Dirichlet elimination. Powers the paper's
//! Thermal problem (steady heat on an irregular domain, Figure 6).

use crate::la::Csr;
use crate::util::shared::SharedOnce;
use anyhow::{bail, Result};

/// A triangle mesh with boundary tags.
#[derive(Debug, Clone)]
pub struct Mesh {
    /// Node coordinates.
    pub nodes: Vec<(f64, f64)>,
    /// Triangles as CCW node index triples.
    pub tris: Vec<[usize; 3]>,
    /// Boundary group per node: None = interior / Neumann part.
    pub dirichlet: Vec<Option<u8>>,
}

impl Mesh {
    /// Annular-sector mesh with a sinusoidally-wavy outer boundary — the
    /// "irregular boundary" analogue of the paper's Fig. 6 thermal domain.
    /// `nr × nth` node grid in (radius, angle). Dirichlet groups:
    /// 0 = inner arc ("left"), 1 = outer arc ("right").
    pub fn annular_sector(nr: usize, nth: usize, waviness: f64) -> Mesh {
        Mesh::annular_sector_graded(nr, nth, waviness, 1.0)
    }

    /// Like [`Mesh::annular_sector`] but with radial grading exponent
    /// `grading`: node radii follow t^grading, clustering elements against
    /// the inner arc. `grading > 1` produces the thin, high-aspect-ratio
    /// boundary-layer elements of a realistic thermal mesh and drives the
    /// stiffness-matrix conditioning into the paper's iteration regime.
    pub fn annular_sector_graded(nr: usize, nth: usize, waviness: f64, grading: f64) -> Mesh {
        assert!(nr >= 2 && nth >= 2);
        let (r0, r1) = (0.5, 1.0);
        let (th0, th1) = (0.0, std::f64::consts::PI);
        let mut nodes = Vec::with_capacity(nr * nth);
        let mut dirichlet = vec![None; nr * nth];
        for it in 0..nth {
            let th = th0 + (th1 - th0) * it as f64 / (nth - 1) as f64;
            // Wavy outer radius makes the element shapes genuinely irregular.
            let router = r1 * (1.0 + waviness * (4.0 * th).sin());
            for ir in 0..nr {
                let t = (ir as f64 / (nr - 1) as f64).powf(grading);
                let r = r0 + (router - r0) * t;
                nodes.push((r * th.cos(), r * th.sin()));
                let id = it * nr + ir;
                if ir == 0 {
                    dirichlet[id] = Some(0);
                } else if ir == nr - 1 {
                    dirichlet[id] = Some(1);
                }
            }
        }
        let mut tris = Vec::with_capacity(2 * (nr - 1) * (nth - 1));
        for it in 0..nth - 1 {
            for ir in 0..nr - 1 {
                let a = it * nr + ir;
                let b = it * nr + ir + 1;
                let c = (it + 1) * nr + ir;
                let d = (it + 1) * nr + ir + 1;
                tris.push([a, b, d]);
                tris.push([a, d, c]);
            }
        }
        Mesh { nodes, tris, dirichlet }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Interior (non-Dirichlet) node count — the FEM unknowns.
    pub fn num_interior(&self) -> usize {
        self.dirichlet.iter().filter(|d| d.is_none()).count()
    }

    /// Signed double-area of triangle t (positive for CCW).
    fn area2(&self, t: &[usize; 3]) -> f64 {
        let (x0, y0) = self.nodes[t[0]];
        let (x1, y1) = self.nodes[t[1]];
        let (x2, y2) = self.nodes[t[2]];
        (x1 - x0) * (y2 - y0) - (x2 - x0) * (y1 - y0)
    }
}

/// Assembled FEM system after Dirichlet elimination.
#[derive(Debug, Clone)]
pub struct FemSystem {
    /// Stiffness on interior nodes.
    pub a: Csr,
    /// Load vector (from Dirichlet lift; no volumetric source here).
    pub b: Vec<f64>,
    /// interior-unknown index → mesh node index.
    pub interior: Vec<usize>,
}

/// Assemble the Laplace (steady heat) problem −Δu = 0 with Dirichlet values
/// `g(group)` on tagged boundary nodes and natural (zero-flux) conditions
/// elsewhere.
pub fn assemble_laplace(mesh: &Mesh, g: &dyn Fn(u8) -> f64) -> Result<FemSystem> {
    assemble_laplace_cached(mesh, g, None)
}

/// [`assemble_laplace`] with an optional stiffness cache. The stiffness
/// matrix depends only on the mesh, never on `g`, so a per-family
/// [`SharedOnce`] lets every sample after the first reuse the assembled `Csr`
/// (one `Arc<Sparsity>`, cloned values) while the load vector and the
/// degenerate-triangle checks still run per call — the returned system is
/// bit-identical to an uncached assembly.
pub fn assemble_laplace_cached(
    mesh: &Mesh,
    g: &dyn Fn(u8) -> f64,
    cache: Option<&SharedOnce<Csr>>,
) -> Result<FemSystem> {
    let nn = mesh.num_nodes();
    // Map node → interior index.
    let mut interior = Vec::new();
    let mut imap = vec![usize::MAX; nn];
    for (i, d) in mesh.dirichlet.iter().enumerate() {
        if d.is_none() {
            imap[i] = interior.len();
            interior.push(i);
        }
    }
    let ni = interior.len();
    if ni == 0 {
        bail!("mesh has no interior nodes");
    }
    let cached = cache.and_then(|c| c.get());
    let need_matrix = cached.is_none();
    let mut trips: Vec<(usize, usize, f64)> =
        if need_matrix { Vec::with_capacity(9 * mesh.tris.len()) } else { Vec::new() };
    let mut b = vec![0.0; ni];

    for t in &mesh.tris {
        let a2 = mesh.area2(t);
        if a2.abs() < 1e-30 {
            bail!("degenerate triangle");
        }
        let (x0, y0) = mesh.nodes[t[0]];
        let (x1, y1) = mesh.nodes[t[1]];
        let (x2, y2) = mesh.nodes[t[2]];
        // Gradients of P1 basis: ∇φᵢ = (bᵢ, cᵢ) / a2.
        let bvec = [y1 - y2, y2 - y0, y0 - y1];
        let cvec = [x2 - x1, x0 - x2, x1 - x0];
        let coef = 1.0 / (2.0 * a2.abs());
        for i in 0..3 {
            for j in 0..3 {
                let kij = coef * (bvec[i] * bvec[j] + cvec[i] * cvec[j]);
                let (gi, gj) = (t[i], t[j]);
                match (mesh.dirichlet[gi], mesh.dirichlet[gj]) {
                    (None, None) => {
                        if need_matrix {
                            trips.push((imap[gi], imap[gj], kij));
                        }
                    }
                    (None, Some(grp)) => b[imap[gi]] -= kij * g(grp),
                    _ => {} // row of a Dirichlet node: eliminated
                }
            }
        }
    }
    let a = match (cached, cache) {
        (Some(hit), _) => (*hit).clone(),
        (None, Some(c)) => {
            let fresh = Csr::from_triplets(ni, ni, &trips);
            (*c.get_or_init(|| fresh)).clone()
        }
        (None, None) => Csr::from_triplets(ni, ni, &trips),
    };
    Ok(FemSystem { a, b, interior })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precond::Identity;
    use crate::solver::{gmres, SolverConfig};

    #[test]
    fn mesh_shapes() {
        let m = Mesh::annular_sector(6, 10, 0.1);
        assert_eq!(m.num_nodes(), 60);
        assert_eq!(m.tris.len(), 2 * 5 * 9);
        // all triangles non-degenerate with positive orientation
        for t in &m.tris {
            assert!(m.area2(t) > 0.0);
        }
        assert_eq!(m.num_interior(), 60 - 2 * 10);
    }

    #[test]
    fn stiffness_is_symmetric() {
        let m = Mesh::annular_sector(8, 12, 0.15);
        let sys = assemble_laplace(&m, &|_| 0.0).unwrap();
        assert!(sys.a.asymmetry() < 1e-12);
        sys.a.validate().unwrap();
    }

    #[test]
    fn constant_dirichlet_gives_constant_solution() {
        // u ≡ 5 on the whole boundary ⇒ u ≡ 5 inside (discrete max principle).
        let m = Mesh::annular_sector(7, 11, 0.1);
        let sys = assemble_laplace(&m, &|_| 5.0).unwrap();
        let mut x = vec![0.0; sys.b.len()];
        let s = gmres(&sys.a, &sys.b, &mut x, &Identity, &SolverConfig::default().with_tol(1e-12));
        assert!(s.converged());
        for &v in &x {
            assert!((v - 5.0).abs() < 1e-8, "{v}");
        }
    }

    #[test]
    fn cached_assembly_is_bit_identical_and_shares_structure() {
        let m = Mesh::annular_sector(8, 12, 0.15);
        let cache = SharedOnce::new();
        let g1 = |grp: u8| if grp == 0 { -3.0 } else { 7.0 };
        let g2 = |grp: u8| if grp == 0 { 20.0 } else { -5.0 };
        let fresh1 = assemble_laplace(&m, &g1).unwrap();
        let fresh2 = assemble_laplace(&m, &g2).unwrap();
        let c1 = assemble_laplace_cached(&m, &g1, Some(&cache)).unwrap();
        let c2 = assemble_laplace_cached(&m, &g2, Some(&cache)).unwrap();
        assert_eq!(fresh1.a, c1.a);
        assert_eq!(fresh2.a, c2.a);
        for (u, v) in fresh1.b.iter().zip(&c1.b) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        for (u, v) in fresh2.b.iter().zip(&c2.b) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        // Cache hits share one Arc<Sparsity>.
        assert!(std::sync::Arc::ptr_eq(c1.a.sparsity(), c2.a.sparsity()));
    }

    #[test]
    fn solution_bounded_by_boundary_values() {
        // Maximum principle: with boundary values in {-100, 100}, the interior
        // solution stays within [-100, 100].
        let m = Mesh::annular_sector(9, 15, 0.2);
        let sys = assemble_laplace(&m, &|grp| if grp == 0 { -100.0 } else { 100.0 }).unwrap();
        let mut x = vec![0.0; sys.b.len()];
        let s = gmres(&sys.a, &sys.b, &mut x, &Identity, &SolverConfig::default().with_tol(1e-11));
        assert!(s.converged());
        for &v in &x {
            assert!((-100.0..=100.0).contains(&v), "{v}");
        }
    }
}
