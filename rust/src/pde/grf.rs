//! Gaussian random fields on a periodic n×n grid via spectral synthesis —
//! the parameter generator for the Darcy (permeability) and Helmholtz
//! (wavenumber) families, mirroring the paper's GRF-sampled coefficients.
//!
//! The field has a squared-exponential-like power spectrum
//! `S(k) ∝ (|k|² + τ²)^(−α)` (the standard FNO-Darcy construction); `α`
//! controls smoothness, `τ` the correlation length.

use super::fft::{fft2, ifft2};
use crate::la::C64;
use crate::util::prng::Rng;

/// GRF sampler configuration.
#[derive(Debug, Clone, Copy)]
pub struct GrfConfig {
    /// Smoothness exponent (α > 1 for a.s. continuous fields).
    pub alpha: f64,
    /// Inverse correlation length.
    pub tau: f64,
}

impl Default for GrfConfig {
    fn default() -> Self {
        GrfConfig { alpha: 2.0, tau: 3.0 }
    }
}

/// Sample a zero-mean GRF on an n×n grid (n must be a power of two).
/// Returns row-major values normalized to unit empirical std.
pub fn sample(n: usize, cfg: &GrfConfig, rng: &mut Rng) -> Vec<f64> {
    assert!(n.is_power_of_two(), "grf grid must be a power of two, got {n}");
    // White noise in physical space.
    let mut field: Vec<C64> = (0..n * n).map(|_| C64::new(rng.normal(), 0.0)).collect();
    fft2(&mut field, n);
    // Shape the spectrum.
    for r in 0..n {
        let kr = freq(r, n);
        for c in 0..n {
            let kc = freq(c, n);
            let k2 = kr * kr + kc * kc;
            let s = (k2 + cfg.tau * cfg.tau).powf(-cfg.alpha / 2.0);
            field[r * n + c] = field[r * n + c].scale(s);
        }
    }
    // Remove the mean (k = 0 mode).
    field[0] = C64::ZERO;
    ifft2(&mut field, n);
    let mut out: Vec<f64> = field.iter().map(|z| z.re).collect();
    // Normalize to unit std so downstream transforms (exp, affine) are stable.
    let mean = out.iter().sum::<f64>() / out.len() as f64;
    let var = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / out.len() as f64;
    let inv = if var > 0.0 { 1.0 / var.sqrt() } else { 1.0 };
    for v in &mut out {
        *v = (*v - mean) * inv;
    }
    out
}

fn freq(i: usize, n: usize) -> f64 {
    // FFT bin → signed integer frequency.
    if i <= n / 2 {
        i as f64
    } else {
        i as f64 - n as f64
    }
}

/// Downsample (or keep) a GRF from a `src`-sized grid to `dst` (dst ≤ src,
/// src divisible by dst) by strided sampling — used when the PDE grid is not
/// a power of two.
pub fn resample(field: &[f64], src: usize, dst: usize) -> Vec<f64> {
    assert_eq!(field.len(), src * src);
    if src == dst {
        return field.to_vec();
    }
    let mut out = Vec::with_capacity(dst * dst);
    for r in 0..dst {
        for c in 0..dst {
            let sr = r * src / dst;
            let sc = c * src / dst;
            out.push(field[sr * src + sc]);
        }
    }
    out
}

/// Smallest power of two ≥ x.
pub fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_variance_zero_mean() {
        let mut rng = Rng::new(7);
        let f = sample(32, &GrfConfig::default(), &mut rng);
        let mean = f.iter().sum::<f64>() / f.len() as f64;
        let var = f.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / f.len() as f64;
        assert!(mean.abs() < 1e-10);
        assert!((var - 1.0).abs() < 1e-10);
    }

    #[test]
    fn smoothness_increases_with_alpha() {
        // Mean squared neighbour difference should shrink as alpha grows.
        let rough_cfg = GrfConfig { alpha: 1.2, tau: 3.0 };
        let smooth_cfg = GrfConfig { alpha: 4.0, tau: 3.0 };
        let rough = sample(64, &rough_cfg, &mut Rng::new(3));
        let smooth = sample(64, &smooth_cfg, &mut Rng::new(3));
        let grad2 = |f: &[f64]| {
            let n = 64;
            let mut s = 0.0;
            for r in 0..n {
                for c in 0..n - 1 {
                    let d = f[r * n + c + 1] - f[r * n + c];
                    s += d * d;
                }
            }
            s
        };
        assert!(grad2(&smooth) < grad2(&rough), "{} vs {}", grad2(&smooth), grad2(&rough));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = sample(16, &GrfConfig::default(), &mut Rng::new(9));
        let b = sample(16, &GrfConfig::default(), &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn resample_strides() {
        let src: Vec<f64> = (0..16).map(|i| i as f64).collect(); // 4x4
        let d = resample(&src, 4, 2);
        assert_eq!(d, vec![0.0, 2.0, 8.0, 10.0]);
    }
}
