//! Radix-2 complex FFT (iterative Cooley–Tukey) — substrate for the
//! spectral Gaussian-random-field sampler. Sizes are powers of two chosen by
//! the problem generators, so a radix-2 kernel is sufficient.

use crate::la::C64;

/// In-place forward FFT of length 2^p.
pub fn fft(x: &mut [C64]) {
    transform(x, false);
}

/// In-place inverse FFT (normalized by 1/n).
pub fn ifft(x: &mut [C64]) {
    transform(x, true);
    let inv = 1.0 / x.len() as f64;
    for v in x.iter_mut() {
        *v = v.scale(inv);
    }
}

fn transform(x: &mut [C64], inverse: bool) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two, got {n}");
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            x.swap(i, j);
        }
    }
    // Butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = C64::new(ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = C64::ONE;
            for k in 0..len / 2 {
                let u = x[i + k];
                let v = x[i + k + len / 2] * w;
                x[i + k] = u + v;
                x[i + k + len / 2] = u - v;
                w *= wlen;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// 2-D FFT over a row-major `n × n` grid, in place.
pub fn fft2(x: &mut [C64], n: usize) {
    assert_eq!(x.len(), n * n);
    // Rows.
    for r in 0..n {
        fft(&mut x[r * n..(r + 1) * n]);
    }
    // Columns via transpose-fft-transpose.
    transpose(x, n);
    for r in 0..n {
        fft(&mut x[r * n..(r + 1) * n]);
    }
    transpose(x, n);
}

/// 2-D inverse FFT, in place.
pub fn ifft2(x: &mut [C64], n: usize) {
    assert_eq!(x.len(), n * n);
    for r in 0..n {
        ifft(&mut x[r * n..(r + 1) * n]);
    }
    transpose(x, n);
    for r in 0..n {
        ifft(&mut x[r * n..(r + 1) * n]);
    }
    transpose(x, n);
}

fn transpose(x: &mut [C64], n: usize) {
    for i in 0..n {
        for j in i + 1..n {
            x.swap(i * n + j, j * n + i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let orig: Vec<C64> = (0..64).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut x = orig.clone();
        fft(&mut x);
        ifft(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((*a - *b).abs() < 1e-12);
        }
    }

    #[test]
    fn delta_transforms_to_ones() {
        let mut x = vec![C64::ZERO; 8];
        x[0] = C64::ONE;
        fft(&mut x);
        for v in &x {
            assert!((*v - C64::ONE).abs() < 1e-14);
        }
    }

    #[test]
    fn single_mode_is_a_spike() {
        // x[t] = exp(2πi·3t/16) → spectrum concentrated at bin 3.
        let n = 16;
        let mut x: Vec<C64> = (0..n)
            .map(|t| {
                let ph = 2.0 * std::f64::consts::PI * 3.0 * t as f64 / n as f64;
                C64::new(ph.cos(), ph.sin())
            })
            .collect();
        fft(&mut x);
        for (k, v) in x.iter().enumerate() {
            if k == 3 {
                assert!((v.abs() - n as f64).abs() < 1e-10);
            } else {
                assert!(v.abs() < 1e-10, "bin {k} = {v:?}");
            }
        }
    }

    #[test]
    fn parseval_2d() {
        let mut rng = Rng::new(2);
        let n = 16;
        let orig: Vec<C64> = (0..n * n).map(|_| C64::new(rng.normal(), 0.0)).collect();
        let mut x = orig.clone();
        fft2(&mut x, n);
        let e_time: f64 = orig.iter().map(|z| z.norm_sqr()).sum();
        let e_freq: f64 = x.iter().map(|z| z.norm_sqr()).sum::<f64>() / (n * n) as f64;
        assert!((e_time - e_freq).abs() < 1e-8 * e_time);
        ifft2(&mut x, n);
        for (a, b) in x.iter().zip(&orig) {
            assert!((*a - *b).abs() < 1e-10);
        }
    }
}
