//! PDE problem families — the paper's four datasets (Appendix D.2), each a
//! generator of *parameterized* sparse linear systems:
//!
//! | Family     | PDE                                | Discretization | Parameters (sort key)       |
//! |------------|------------------------------------|----------------|-----------------------------|
//! | Darcy      | −∇·(K∇h) = f, K lognormal GRF      | FVM 5-point    | GRF permeability field      |
//! | Thermal    | ΔT = 0, irregular domain           | P1 FEM         | boundary temperatures       |
//! | Poisson    | Δu = f, Chebyshev data             | FDM 5-point    | Chebyshev coefficients      |
//! | Helmholtz  | Δu + k²u = f, k from GRF           | FDM 5-point    | GRF wavenumber field        |

pub mod chebyshev;
pub mod darcy;
pub mod fem;
pub mod fft;
pub mod grf;
pub mod grid;
pub mod helmholtz;
pub mod poisson;
pub mod thermal;

use crate::solver::LinearSystem;
use crate::util::prng::Rng;
use anyhow::Result;

/// A family of PDE problems sharing structure but varying in parameters —
/// the unit the coordinator's pipeline generates, sorts and solves.
pub trait ProblemFamily: Send + Sync {
    /// Family tag (e.g. "darcy").
    fn name(&self) -> &'static str;

    /// Number of unknowns per system for this configuration.
    fn num_unknowns(&self) -> usize;

    /// Sample the `id`-th problem instance with an independent RNG stream.
    fn sample(&self, id: usize, rng: &mut Rng) -> Result<LinearSystem>;

    /// Sample only the parameter vector of instance `id` — must draw from
    /// `rng` exactly like [`ProblemFamily::sample`] so the two agree. The
    /// pipeline uses this cheap pass to sort before any matrix is assembled.
    fn sample_params(&self, id: usize, rng: &mut Rng) -> Result<Vec<f64>> {
        Ok(self.sample(id, rng)?.params)
    }

    /// Side length of the field grid for dataset export (0 when the family
    /// is not grid-structured, e.g. FEM).
    fn field_side(&self) -> usize {
        let n = (self.num_unknowns() as f64).sqrt() as usize;
        if n * n == self.num_unknowns() {
            n
        } else {
            0
        }
    }

    /// The input-field values (e.g. permeability) paired with a solution for
    /// NO training export; default: the raw parameter vector.
    fn input_field(&self, sys: &LinearSystem) -> Vec<f64> {
        sys.params.clone()
    }
}

/// Which of the paper's four datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FamilyKind {
    Darcy,
    Thermal,
    Poisson,
    Helmholtz,
}

impl FamilyKind {
    pub const ALL: [FamilyKind; 4] =
        [FamilyKind::Darcy, FamilyKind::Thermal, FamilyKind::Poisson, FamilyKind::Helmholtz];

    pub fn parse(s: &str) -> Result<FamilyKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "darcy" => FamilyKind::Darcy,
            "thermal" => FamilyKind::Thermal,
            "poisson" => FamilyKind::Poisson,
            "helmholtz" => FamilyKind::Helmholtz,
            other => anyhow::bail!("unknown family {other:?}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            FamilyKind::Darcy => "Darcy",
            FamilyKind::Thermal => "Thermal",
            FamilyKind::Poisson => "Poisson",
            FamilyKind::Helmholtz => "Helmholtz",
        }
    }

    /// Build the family with approximately `unknowns` unknowns.
    pub fn build(&self, unknowns: usize) -> Box<dyn ProblemFamily> {
        self.build_with(unknowns, None)
    }

    /// Like [`FamilyKind::build`] with an optional GRF smoothness override
    /// for the GRF-driven families (no-op for the others).
    pub fn build_with(&self, unknowns: usize, grf_alpha: Option<f64>) -> Box<dyn ProblemFamily> {
        match self {
            FamilyKind::Darcy => {
                let mut f = darcy::DarcyFamily::with_unknowns(unknowns);
                if let Some(a) = grf_alpha {
                    f.grf.alpha = a;
                }
                Box::new(f)
            }
            FamilyKind::Thermal => Box::new(thermal::ThermalFamily::with_unknowns(unknowns)),
            FamilyKind::Poisson => Box::new(poisson::PoissonFamily::with_unknowns(unknowns)),
            FamilyKind::Helmholtz => {
                let mut f = helmholtz::HelmholtzFamily::with_unknowns(unknowns);
                if let Some(a) = grf_alpha {
                    f.grf.alpha = a;
                }
                Box::new(f)
            }
        }
    }
}

/// Generate `count` problem instances with per-instance RNG streams derived
/// from `seed` (instance i is identical no matter how many are drawn or in
/// which order — required for the pipeline's parallel generation stage).
pub fn generate(family: &dyn ProblemFamily, count: usize, seed: u64) -> Result<Vec<LinearSystem>> {
    let master = Rng::new(seed);
    (0..count)
        .map(|i| {
            let mut rng = master.split(i as u64);
            family.sample(i, &mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_parse_roundtrip() {
        for f in FamilyKind::ALL {
            assert_eq!(FamilyKind::parse(f.label()).unwrap(), f);
        }
        assert!(FamilyKind::parse("wave").is_err());
    }

    #[test]
    fn generation_is_deterministic_and_streamed() {
        let fam = FamilyKind::Darcy.build(100);
        let a = generate(fam.as_ref(), 3, 7).unwrap();
        let b = generate(fam.as_ref(), 5, 7).unwrap();
        // The first 3 of a 5-batch must equal the 3-batch (stream independence).
        for i in 0..3 {
            assert_eq!(a[i].b, b[i].b, "instance {i}");
            assert_eq!(a[i].params, b[i].params);
        }
    }

    #[test]
    fn sample_params_agrees_with_sample() {
        for kind in FamilyKind::ALL {
            let fam = kind.build(120);
            let master = Rng::new(99);
            let full = fam.sample(0, &mut master.split(0)).unwrap();
            let cheap = fam.sample_params(0, &mut master.split(0)).unwrap();
            assert_eq!(full.params, cheap, "{kind:?}");
        }
    }

    #[test]
    fn all_families_produce_valid_systems() {
        for kind in FamilyKind::ALL {
            let fam = kind.build(150);
            let sys = generate(fam.as_ref(), 2, 1).unwrap();
            for s in &sys {
                s.a.validate().unwrap();
                assert_eq!(s.a.nrows(), s.b.len());
                assert!(!s.params.is_empty(), "{kind:?} has empty params");
                assert!(s.b.iter().any(|v| *v != 0.0), "{kind:?} has zero rhs");
            }
        }
    }
}
