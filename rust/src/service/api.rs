//! The `skr serve` JSON API: the job-spec wire format and the route table.
//!
//! | Method & path      | Meaning                                   |
//! |--------------------|-------------------------------------------|
//! | `POST /jobs`       | submit a generation job (202 / 429 / 503) |
//! | `GET /jobs`        | list all jobs + queue state               |
//! | `GET /jobs/:id`    | one job incl. live progress               |
//! | `DELETE /jobs/:id` | cancel (queued or in-flight)              |
//! | `GET /metrics`     | Prometheus text (aggregate + service)     |
//! | `GET /healthz`     | liveness                                  |
//! | `POST /shutdown`   | graceful drain                            |
//!
//! All bodies are [`Json`] from `util::json` — the same parser the journal
//! and trace files use, hardened against malformed input since request
//! bodies are untrusted.

use super::http::{Request, Response};
use super::queue::{CancelResult, JobView, SubmitRejected};
use super::Service;
use crate::coordinator::{PipelineConfig, SortStrategy};
use crate::pde::FamilyKind;
use crate::precond::PrecondKind;
use crate::solver::Engine;
use crate::util::args::Args;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// A generation job as submitted over the wire — deliberately stored as the
/// user's strings/numbers (not parsed enums) so the journal round-trips
/// exactly; [`JobSpec::to_config`] validates and lowers to [`PipelineConfig`]
/// with the *same defaults* as `skr generate`, keeping service output
/// byte-identical to the batch CLI for the same spec.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub family: String,
    pub unknowns: usize,
    pub count: usize,
    pub engine: String,
    pub precond: String,
    pub sort: String,
    pub threads: usize,
    pub tol: f64,
    pub m: usize,
    pub k: usize,
    pub max_iters: usize,
    pub seed: u64,
    /// Dataset output directory (None = solve but export nothing).
    pub out: Option<String>,
    pub grf_alpha: Option<f64>,
}

impl Default for JobSpec {
    fn default() -> Self {
        // Mirrors `PipelineConfig::from_args` defaults field by field.
        JobSpec {
            family: "darcy".into(),
            unknowns: 2500,
            count: 64,
            engine: "skr".into(),
            precond: "none".into(),
            sort: "greedy".into(),
            threads: 1,
            tol: 1e-8,
            m: 30,
            k: 10,
            max_iters: 10_000,
            seed: 0,
            out: None,
            grf_alpha: None,
        }
    }
}

impl JobSpec {
    /// Build from CLI args (`skr submit` shares `skr generate`'s flags).
    pub fn from_args(args: &Args) -> JobSpec {
        let d = JobSpec::default();
        JobSpec {
            family: args.str_or("family", &d.family),
            unknowns: args.num_or("n", d.unknowns),
            count: args.num_or("count", d.count),
            engine: args.str_or("engine", &d.engine),
            precond: args.str_or("precond", &d.precond),
            sort: args.str_or("sort", &d.sort),
            threads: args.num_or("threads", d.threads).max(1),
            tol: args.num_or("tol", d.tol),
            m: args.num_or("m", d.m),
            k: args.num_or("k", d.k),
            max_iters: args.num_or("max-iters", d.max_iters),
            seed: args.num_or("seed", d.seed),
            out: args.get("out").map(str::to_string),
            grf_alpha: args.get("grf-alpha").and_then(|v| v.parse().ok()),
        }
    }

    /// Parse from an untrusted request body; unknown keys are ignored,
    /// missing keys fall back to the `skr generate` defaults.
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        if !matches!(j, Json::Obj(_)) {
            bail!("job spec must be a JSON object");
        }
        let d = JobSpec::default();
        let str_or = |key: &str, dflt: &str| -> Result<String> {
            match j.get(key) {
                None => Ok(dflt.to_string()),
                Some(v) => {
                    Ok(v.as_str().with_context(|| format!("{key:?} must be a string"))?.to_string())
                }
            }
        };
        let num_or = |key: &str, dflt: f64| -> Result<f64> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v.as_f64().with_context(|| format!("{key:?} must be a number")),
            }
        };
        let usize_or = |key: &str, dflt: usize| -> Result<usize> {
            let v = num_or(key, dflt as f64)?;
            if v < 0.0 || v.fract() != 0.0 {
                bail!("{key:?} must be a non-negative integer, got {v}");
            }
            Ok(v as usize)
        };
        Ok(JobSpec {
            family: str_or("family", &d.family)?,
            unknowns: usize_or("n", d.unknowns)?,
            count: usize_or("count", d.count)?,
            engine: str_or("engine", &d.engine)?,
            precond: str_or("precond", &d.precond)?,
            sort: str_or("sort", &d.sort)?,
            threads: usize_or("threads", d.threads)?.max(1),
            tol: num_or("tol", d.tol)?,
            m: usize_or("m", d.m)?,
            k: usize_or("k", d.k)?,
            max_iters: usize_or("max_iters", d.max_iters)?,
            seed: usize_or("seed", d.seed as usize)? as u64,
            out: j.get("out").and_then(|v| v.as_str()).map(str::to_string),
            grf_alpha: j.get("grf_alpha").and_then(|v| v.as_f64()),
        })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("family", Json::Str(self.family.clone())),
            ("n", Json::Num(self.unknowns as f64)),
            ("count", Json::Num(self.count as f64)),
            ("engine", Json::Str(self.engine.clone())),
            ("precond", Json::Str(self.precond.clone())),
            ("sort", Json::Str(self.sort.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("tol", Json::Num(self.tol)),
            ("m", Json::Num(self.m as f64)),
            ("k", Json::Num(self.k as f64)),
            ("max_iters", Json::Num(self.max_iters as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ];
        if let Some(out) = &self.out {
            pairs.push(("out", Json::Str(out.clone())));
        }
        if let Some(a) = self.grf_alpha {
            pairs.push(("grf_alpha", Json::Num(a)));
        }
        Json::obj(pairs)
    }

    /// Validate and lower to a [`PipelineConfig`] (the submit handler calls
    /// this so bad specs are rejected with 400 before they ever enqueue).
    pub fn to_config(&self) -> Result<PipelineConfig> {
        let mut cfg = PipelineConfig {
            family: FamilyKind::parse(&self.family)?,
            unknowns: self.unknowns,
            count: self.count,
            engine: Engine::parse(&self.engine)?,
            precond: PrecondKind::parse(&self.precond)?,
            sort: SortStrategy::parse(&self.sort)?,
            threads: self.threads.max(1),
            seed: self.seed,
            out_dir: self.out.as_ref().map(std::path::PathBuf::from),
            grf_alpha: self.grf_alpha,
            ..Default::default()
        };
        if self.count == 0 {
            bail!("count must be at least 1");
        }
        cfg.solver.tol = self.tol;
        cfg.solver.m = self.m;
        cfg.solver.k = self.k;
        cfg.solver.max_iters = self.max_iters;
        Ok(cfg)
    }
}

/// One job rendered for the API.
pub fn job_json(v: &JobView) -> Json {
    let p = &v.progress;
    Json::obj(vec![
        ("id", Json::Num(v.id as f64)),
        ("state", Json::Str(v.state.label().to_string())),
        ("spec", v.spec.to_json()),
        (
            "progress",
            Json::obj(vec![
                ("done", Json::Num(p.done as f64)),
                ("total", Json::Num(p.total as f64)),
                ("sparsity_reuse", Json::Num(p.sparsity_reuse as f64)),
                ("symbolic_reuse", Json::Num(p.symbolic_reuse as f64)),
                ("workspace_reuse", Json::Num(p.workspace_reuse as f64)),
            ]),
        ),
        ("error", v.error.clone().map_or(Json::Null, Json::Str)),
        ("dataset", v.dataset.clone().map_or(Json::Null, Json::Str)),
    ])
}

fn err_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

/// Dispatch one request against the service.
pub fn handle(svc: &Service, req: &Request) -> Response {
    let segs = req.segments();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => Response::json(
            200,
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(svc.queue.is_draining())),
            ])
            .dump(),
        ),
        ("GET", ["metrics"]) => Response::text(200, svc.metrics_text()),
        ("POST", ["jobs"]) => submit(svc, req),
        ("GET", ["jobs"]) => {
            let views = svc.queue.list();
            let jobs: Vec<Json> = views.iter().map(job_json).collect();
            Response::json(
                200,
                Json::obj(vec![
                    ("jobs", Json::Arr(jobs)),
                    ("queued", Json::Num(svc.queue.queued_len() as f64)),
                    ("running", Json::Num(svc.queue.running_len() as f64)),
                    ("draining", Json::Bool(svc.queue.is_draining())),
                ])
                .dump(),
            )
        }
        ("GET", ["jobs", id]) => match parse_id(id) {
            Some(id) => match svc.queue.get(id) {
                Some(v) => Response::json(200, job_json(&v).dump()),
                None => Response::json(404, err_body(&format!("no job {id}"))),
            },
            None => Response::json(400, err_body("job id must be an integer")),
        },
        ("DELETE", ["jobs", id]) => match parse_id(id) {
            Some(id) => cancel(svc, id),
            None => Response::json(400, err_body("job id must be an integer")),
        },
        ("POST", ["shutdown"]) => {
            svc.begin_drain();
            Response::json(200, Json::obj(vec![("draining", Json::Bool(true))]).dump())
        }
        ("GET" | "POST" | "DELETE", _) => Response::json(404, err_body("no such endpoint")),
        _ => Response::json(405, err_body("method not allowed")),
    }
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse().ok()
}

fn submit(svc: &Service, req: &Request) -> Response {
    let body = match std::str::from_utf8(&req.body) {
        Ok(s) => s,
        Err(_) => return Response::json(400, err_body("body must be UTF-8 JSON")),
    };
    let parsed = if body.trim().is_empty() { Ok(Json::obj(vec![])) } else { Json::parse(body) };
    let spec = match parsed.and_then(|j| JobSpec::from_json(&j)) {
        Ok(spec) => spec,
        Err(e) => return Response::json(400, err_body(&format!("bad job spec: {e:#}"))),
    };
    // Reject invalid configs before they occupy a queue slot.
    if let Err(e) = spec.to_config() {
        return Response::json(400, err_body(&format!("bad job spec: {e:#}")));
    }
    match svc.submit(spec) {
        Ok(id) => Response::json(
            202,
            Json::obj(vec![("id", Json::Num(id as f64)), ("state", Json::Str("queued".into()))])
                .dump(),
        ),
        Err(SubmitRejected::Full) => Response::json(429, err_body("job queue is full"))
            .with_header("Retry-After", "1"),
        Err(SubmitRejected::Draining) => {
            Response::json(503, err_body("service is draining"))
        }
    }
}

fn cancel(svc: &Service, id: u64) -> Response {
    match svc.cancel(id) {
        CancelResult::NotFound => Response::json(404, err_body(&format!("no job {id}"))),
        CancelResult::AlreadyTerminal(state) => Response::json(
            409,
            Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("state", Json::Str(state.label().to_string())),
                ("error", Json::Str("job already finished".into())),
            ])
            .dump(),
        ),
        CancelResult::CancelledQueued => Response::json(
            200,
            Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("state", Json::Str("cancelled".into())),
            ])
            .dump(),
        ),
        CancelResult::CancellingRunning => Response::json(
            202,
            Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("state", Json::Str("cancelling".into())),
            ])
            .dump(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        let mut spec = JobSpec::default();
        spec.family = "helmholtz".into();
        spec.unknowns = 400;
        spec.count = 7;
        spec.out = Some("results/x".into());
        spec.grf_alpha = Some(2.5);
        let back = JobSpec::from_json(&Json::parse(&spec.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn empty_spec_uses_generate_defaults() {
        let spec = JobSpec::from_json(&Json::obj(vec![])).unwrap();
        let cfg = spec.to_config().unwrap();
        let d = PipelineConfig::default();
        assert_eq!(cfg.family, d.family);
        assert_eq!(cfg.unknowns, d.unknowns);
        assert_eq!(cfg.count, d.count);
        assert!((cfg.solver.tol - 1e-8).abs() < 1e-20);
        assert_eq!(cfg.solver.m, 30);
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in [
            r#"{"family":"nope"}"#,
            r#"{"engine":17}"#,
            r#"{"count":-3}"#,
            r#"{"n":2.5}"#,
            r#"{"count":0}"#,
        ] {
            let r = Json::parse(bad)
                .map_err(anyhow::Error::from)
                .and_then(|j| JobSpec::from_json(&j))
                .and_then(|s| s.to_config());
            assert!(r.is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn from_args_matches_defaults() {
        let args = Args::parse(std::iter::empty());
        let spec = JobSpec::from_args(&args);
        assert_eq!(spec, JobSpec::default());
    }
}
