//! `skr serve` — a resident data-generation daemon.
//!
//! The batch CLI solves one dataset per process; this subsystem turns the
//! same [`Pipeline`](crate::coordinator::Pipeline) into an always-on service:
//! jobs arrive over a minimal HTTP/1.1 JSON API ([`api`]), wait in a bounded
//! FIFO ([`queue`], 429 + `Retry-After` on overflow), execute on a worker
//! pool ([`worker`]) under cooperative cancellation, and every lifecycle
//! transition lands in an append-only JSONL journal ([`journal`]) so a
//! crashed daemon re-queues unfinished work on restart. Completed-job
//! metrics aggregate into a live Prometheus `GET /metrics` endpoint via the
//! existing [`RunMetrics::prometheus_text`]. Std-only, like the rest of the
//! crate: the HTTP framing ([`http`]) is ~150 lines over `TcpStream`.

pub mod api;
pub mod http;
pub mod journal;
pub mod queue;
pub mod worker;

pub use api::JobSpec;
pub use queue::{CancelResult, JobId, JobQueue, JobState, JobView, SubmitRejected};

use crate::coordinator::metrics::RunMetrics;
use crate::util::args::Args;
use anyhow::{Context, Result};
use journal::Journal;
use std::fmt::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Daemon configuration (`skr serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7070` (port 0 = ephemeral).
    pub bind: String,
    /// Concurrent jobs (each job additionally uses its own `threads`).
    pub workers: usize,
    /// Pending-backlog capacity before `POST /jobs` answers 429.
    pub queue_capacity: usize,
    /// Directory holding `journal.jsonl`.
    pub state_dir: PathBuf,
}

impl ServeConfig {
    pub fn from_args(args: &Args) -> ServeConfig {
        let host = args.str_or("host", "127.0.0.1");
        let port: u16 = args.num_or("port", 7070u16);
        ServeConfig {
            bind: format!("{host}:{port}"),
            workers: args.num_or("workers", 1usize).max(1),
            queue_capacity: args.num_or("queue-cap", 64usize).max(1),
            state_dir: PathBuf::from(args.str_or("state-dir", "results/service")),
        }
    }
}

/// Shared state behind every connection handler and worker thread.
pub struct Service {
    pub queue: JobQueue,
    pub journal: Journal,
    /// RunMetrics of all completed jobs, merged (drives `GET /metrics`).
    aggregate: Mutex<RunMetrics>,
    submitted: AtomicUsize,
    done: AtomicUsize,
    failed: AtomicUsize,
    cancelled: AtomicUsize,
    /// Set once the listener is bound; used to self-connect on drain so the
    /// blocking `accept` wakes up.
    local_addr: OnceLock<SocketAddr>,
}

impl Service {
    /// Build the service: open the journal, replay it, and re-queue every
    /// job that never reached a terminal state.
    pub fn new(cfg: &ServeConfig) -> Result<(Arc<Service>, usize)> {
        let journal_path = cfg.state_dir.join("journal.jsonl");
        let replay = Journal::replay(&journal_path)?;
        // Terminal records are dead weight after replay; rewrite the
        // journal down to its live content so it stays bounded across
        // restarts.
        Journal::compact(&journal_path, &replay)?;
        let journal = Journal::open(&journal_path)?;
        let svc = Service {
            queue: JobQueue::new(cfg.queue_capacity, replay.next_id),
            journal,
            aggregate: Mutex::new(RunMetrics::default()),
            submitted: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            cancelled: AtomicUsize::new(0),
            local_addr: OnceLock::new(),
        };
        let replayed = replay.pending.len();
        for (id, spec) in replay.pending {
            svc.queue.requeue(id, spec);
        }
        Ok((Arc::new(svc), replayed))
    }

    /// Journal + enqueue one job.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitRejected> {
        let id = self.queue.submit(spec)?;
        // Journal *after* admission so the record carries the real id; the
        // tiny accept-then-crash window loses only an unacknowledged job.
        let view = self.queue.get(id).expect("job just submitted");
        self.journal.submitted(id, &view.spec);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(id)
    }

    /// Cancel a job; journals immediately when it never started.
    pub fn cancel(&self, id: JobId) -> CancelResult {
        let r = self.queue.cancel(id);
        if r == CancelResult::CancelledQueued {
            self.journal.cancelled(id);
            self.note_outcome(JobState::Cancelled);
        }
        r
    }

    pub(crate) fn absorb_metrics(&self, m: &RunMetrics) {
        self.aggregate.lock().unwrap().merge(m);
    }

    pub(crate) fn note_outcome(&self, state: JobState) {
        match state {
            JobState::Done => self.done.fetch_add(1, Ordering::Relaxed),
            JobState::Failed => self.failed.fetch_add(1, Ordering::Relaxed),
            JobState::Cancelled => self.cancelled.fetch_add(1, Ordering::Relaxed),
            _ => 0,
        };
    }

    /// The `GET /metrics` body: service-level series + the merged
    /// [`RunMetrics`] Prometheus snapshot of all completed jobs.
    pub fn metrics_text(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        counter(
            "skr_service_jobs_submitted_total",
            "jobs accepted by POST /jobs",
            self.submitted.load(Ordering::Relaxed) as f64,
        );
        counter(
            "skr_service_jobs_done_total",
            "jobs completed successfully",
            self.done.load(Ordering::Relaxed) as f64,
        );
        counter(
            "skr_service_jobs_failed_total",
            "jobs that errored",
            self.failed.load(Ordering::Relaxed) as f64,
        );
        counter(
            "skr_service_jobs_cancelled_total",
            "jobs cancelled",
            self.cancelled.load(Ordering::Relaxed) as f64,
        );
        let _ = writeln!(out, "# TYPE skr_service_queue_depth gauge");
        let _ = writeln!(out, "skr_service_queue_depth {}", self.queue.queued_len());
        let _ = writeln!(out, "# TYPE skr_service_jobs_running gauge");
        let _ = writeln!(out, "skr_service_jobs_running {}", self.queue.running_len());
        out.push_str(&self.aggregate.lock().unwrap().prometheus_text());
        out
    }

    /// Start the graceful drain: refuse new jobs, let queued + running work
    /// finish, wake the accept loop so `serve` can return.
    pub fn begin_drain(&self) {
        self.queue.begin_drain();
        if let Some(addr) = self.local_addr.get() {
            // Nudge the blocking accept() so the serve loop observes the
            // drain flag; errors are harmless (the loop may already be gone).
            let _ = TcpStream::connect_timeout(addr, Duration::from_secs(1));
        }
    }
}

/// Bind, spawn the worker pool, serve until drained. Blocks until the
/// graceful shutdown completes; every accepted job has then reached a
/// terminal state.
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let listener =
        TcpListener::bind(&cfg.bind).with_context(|| format!("binding {}", cfg.bind))?;
    let addr = listener.local_addr()?;
    let (svc, replayed) = Service::new(cfg)?;
    svc.local_addr.set(addr).expect("local_addr set once");
    println!(
        "skr serve listening on {addr} ({} worker{}, queue cap {}, journal {})",
        cfg.workers,
        if cfg.workers == 1 { "" } else { "s" },
        cfg.queue_capacity,
        svc.journal.path().display(),
    );
    if replayed > 0 {
        println!("re-queued {replayed} unfinished job(s) from the journal");
    }

    let mut workers = Vec::with_capacity(cfg.workers);
    for _ in 0..cfg.workers {
        let svc = svc.clone();
        workers.push(std::thread::spawn(move || worker::run(svc)));
    }

    for stream in listener.incoming() {
        if svc.queue.is_draining() {
            break;
        }
        let Ok(stream) = stream else { continue };
        let svc = svc.clone();
        std::thread::spawn(move || handle_connection(stream, &svc));
    }

    for w in workers {
        let _ = w.join();
    }
    println!("skr serve drained; all accepted jobs reached a terminal state");
    Ok(())
}

fn handle_connection(mut stream: TcpStream, svc: &Service) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let resp = match http::read_request(&mut stream) {
        Ok(req) => api::handle(svc, &req),
        Err(e) => http::Response::json(
            400,
            crate::util::json::Json::obj(vec![(
                "error",
                crate::util::json::Json::Str(format!("{e:#}")),
            )])
            .dump(),
        ),
    };
    let _ = http::write_response(&mut stream, &resp);
}
