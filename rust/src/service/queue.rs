//! Bounded job queue + registry for `skr serve`.
//!
//! One mutex-guarded table holds every job the daemon has ever seen this
//! run; a FIFO of pending ids feeds the worker pool through a condvar.
//! Capacity bounds only the *pending* backlog — running and finished jobs
//! never count against it, and journal-replayed jobs are re-admitted above
//! capacity (they were already accepted once; rejecting them on restart
//! would drop acknowledged work).

use super::api::JobSpec;
use crate::coordinator::{ProgressSnapshot, RunControl};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

pub type JobId = u64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

struct Job {
    spec: JobSpec,
    state: JobState,
    ctl: Arc<RunControl>,
    error: Option<String>,
    dataset: Option<String>,
}

/// Read-only snapshot of one job for the API layer.
#[derive(Debug, Clone)]
pub struct JobView {
    pub id: JobId,
    pub state: JobState,
    pub spec: JobSpec,
    pub progress: ProgressSnapshot,
    pub error: Option<String>,
    pub dataset: Option<String>,
}

/// Why a submit was turned away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitRejected {
    /// Pending backlog is at capacity — retry later (HTTP 429).
    Full,
    /// The daemon is draining for shutdown (HTTP 503).
    Draining,
}

/// Outcome of a cancel request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelResult {
    NotFound,
    AlreadyTerminal(JobState),
    /// Was still queued: terminal immediately, never ran.
    CancelledQueued,
    /// In flight: token flipped, the worker will stop within one solve.
    CancellingRunning,
}

/// A unit of work handed to a worker thread.
pub struct Task {
    pub id: JobId,
    pub spec: JobSpec,
    pub ctl: Arc<RunControl>,
}

struct Inner {
    jobs: BTreeMap<JobId, Job>,
    pending: VecDeque<JobId>,
    next_id: JobId,
    running: usize,
    draining: bool,
}

pub struct JobQueue {
    inner: Mutex<Inner>,
    ready: Condvar,
    capacity: usize,
}

impl JobQueue {
    pub fn new(capacity: usize, first_id: JobId) -> JobQueue {
        JobQueue {
            inner: Mutex::new(Inner {
                jobs: BTreeMap::new(),
                pending: VecDeque::new(),
                next_id: first_id.max(1),
                running: 0,
                draining: false,
            }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Admit a new job if there is backlog room; returns its fresh id.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitRejected> {
        let mut g = self.inner.lock().unwrap();
        if g.draining {
            return Err(SubmitRejected::Draining);
        }
        if g.pending.len() >= self.capacity {
            return Err(SubmitRejected::Full);
        }
        let id = g.next_id;
        g.next_id += 1;
        g.jobs.insert(
            id,
            Job {
                spec,
                state: JobState::Queued,
                ctl: Arc::new(RunControl::new()),
                error: None,
                dataset: None,
            },
        );
        g.pending.push_back(id);
        drop(g);
        self.ready.notify_one();
        Ok(id)
    }

    /// Re-admit a journaled job on restart under its *original* id —
    /// bypasses the capacity check (the work was already acknowledged).
    pub fn requeue(&self, id: JobId, spec: JobSpec) {
        let mut g = self.inner.lock().unwrap();
        g.next_id = g.next_id.max(id + 1);
        g.jobs.insert(
            id,
            Job {
                spec,
                state: JobState::Queued,
                ctl: Arc::new(RunControl::new()),
                error: None,
                dataset: None,
            },
        );
        g.pending.push_back(id);
        drop(g);
        self.ready.notify_one();
    }

    /// Block until a job is available (or the drain completes); cancelled
    /// queue entries are skipped, not returned.
    pub fn take_next(&self) -> Option<Task> {
        let mut guard = self.inner.lock().unwrap();
        loop {
            {
                let g = &mut *guard; // split field borrows (pending/jobs/running)
                while let Some(id) = g.pending.pop_front() {
                    let job = g.jobs.get_mut(&id).expect("pending id without job entry");
                    if job.state != JobState::Queued {
                        continue; // cancelled while queued
                    }
                    job.state = JobState::Running;
                    let task = Task { id, spec: job.spec.clone(), ctl: job.ctl.clone() };
                    g.running += 1;
                    return Some(task);
                }
                if g.draining {
                    return None;
                }
            }
            guard = self.ready.wait(guard).unwrap();
        }
    }

    /// Record a worker's terminal outcome for `id`.
    pub fn finish(&self, id: JobId, state: JobState, error: Option<String>, dataset: Option<String>) {
        debug_assert!(state.is_terminal());
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard; // split field borrows (jobs vs running)
        if let Some(job) = g.jobs.get_mut(&id) {
            if job.state == JobState::Running {
                g.running -= 1;
            }
            job.state = state;
            job.error = error;
            job.dataset = dataset;
        }
    }

    pub fn cancel(&self, id: JobId) -> CancelResult {
        let mut g = self.inner.lock().unwrap();
        let Some(job) = g.jobs.get_mut(&id) else { return CancelResult::NotFound };
        match job.state {
            s if s.is_terminal() => CancelResult::AlreadyTerminal(s),
            JobState::Queued => {
                job.state = JobState::Cancelled;
                // Leave the id in `pending`; take_next skips non-queued ids.
                CancelResult::CancelledQueued
            }
            _ => {
                job.ctl.cancel();
                CancelResult::CancellingRunning
            }
        }
    }

    pub fn get(&self, id: JobId) -> Option<JobView> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(&id).map(|job| view(id, job))
    }

    pub fn list(&self) -> Vec<JobView> {
        let g = self.inner.lock().unwrap();
        g.jobs.iter().map(|(&id, job)| view(id, job)).collect()
    }

    /// Stop admitting work and wake all workers so they drain the backlog
    /// and exit.
    pub fn begin_drain(&self) {
        self.inner.lock().unwrap().draining = true;
        self.ready.notify_all();
    }

    pub fn is_draining(&self) -> bool {
        self.inner.lock().unwrap().draining
    }

    pub fn queued_len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.jobs.values().filter(|j| j.state == JobState::Queued).count()
    }

    pub fn running_len(&self) -> usize {
        self.inner.lock().unwrap().running
    }
}

fn view(id: JobId, job: &Job) -> JobView {
    JobView {
        id,
        state: job.state,
        spec: job.spec.clone(),
        progress: job.ctl.progress(),
        error: job.error.clone(),
        dataset: job.dataset.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec::default()
    }

    #[test]
    fn bounded_submit_then_429_equivalent() {
        let q = JobQueue::new(2, 1);
        assert_eq!(q.submit(spec()), Ok(1));
        assert_eq!(q.submit(spec()), Ok(2));
        assert_eq!(q.submit(spec()), Err(SubmitRejected::Full));
        // Accepted work is still there.
        assert_eq!(q.queued_len(), 2);
        // Draining a slot re-opens capacity.
        let t = q.take_next().unwrap();
        assert_eq!(t.id, 1);
        assert_eq!(q.submit(spec()), Ok(3));
    }

    #[test]
    fn fifo_order_and_states() {
        let q = JobQueue::new(8, 1);
        let a = q.submit(spec()).unwrap();
        let b = q.submit(spec()).unwrap();
        assert_eq!(q.take_next().unwrap().id, a);
        assert_eq!(q.get(a).unwrap().state, JobState::Running);
        q.finish(a, JobState::Done, None, Some("out".into()));
        assert_eq!(q.get(a).unwrap().state, JobState::Done);
        assert_eq!(q.get(a).unwrap().dataset.as_deref(), Some("out"));
        assert_eq!(q.take_next().unwrap().id, b);
        assert_eq!(q.running_len(), 1);
    }

    #[test]
    fn cancel_queued_never_runs() {
        let q = JobQueue::new(8, 1);
        let a = q.submit(spec()).unwrap();
        let b = q.submit(spec()).unwrap();
        assert_eq!(q.cancel(a), CancelResult::CancelledQueued);
        assert_eq!(q.get(a).unwrap().state, JobState::Cancelled);
        // The cancelled job is skipped; b comes out first.
        assert_eq!(q.take_next().unwrap().id, b);
        // Cancelling again reports terminal.
        assert_eq!(q.cancel(a), CancelResult::AlreadyTerminal(JobState::Cancelled));
        assert_eq!(q.cancel(999), CancelResult::NotFound);
    }

    #[test]
    fn cancel_running_flips_token() {
        let q = JobQueue::new(8, 1);
        let a = q.submit(spec()).unwrap();
        let task = q.take_next().unwrap();
        assert!(!task.ctl.is_cancelled());
        assert_eq!(q.cancel(a), CancelResult::CancellingRunning);
        assert!(task.ctl.is_cancelled());
    }

    #[test]
    fn drain_wakes_and_exhausts() {
        let q = std::sync::Arc::new(JobQueue::new(8, 1));
        q.submit(spec()).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut served = 0;
            while let Some(t) = q2.take_next() {
                q2.finish(t.id, JobState::Done, None, None);
                served += 1;
            }
            served
        });
        // Give the worker a moment, then drain; it must serve 1 then exit.
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.begin_drain();
        assert_eq!(h.join().unwrap(), 1);
        assert_eq!(q.submit(spec()), Err(SubmitRejected::Draining));
    }

    #[test]
    fn requeue_bypasses_capacity_and_preserves_ids() {
        let q = JobQueue::new(1, 10);
        q.submit(spec()).unwrap(); // fills capacity (id 10)
        q.requeue(3, spec());
        q.requeue(7, spec());
        assert_eq!(q.queued_len(), 3);
        // Fresh submits continue above the replayed id space.
        let t = q.take_next().unwrap();
        assert_eq!(t.id, 10);
        let fresh = q.submit(spec()).unwrap();
        assert_eq!(fresh, 11);
    }
}
