//! Crash-safe job journal: an append-only JSONL file recording every job
//! lifecycle transition (`submitted` → `started` → `done`/`failed`/
//! `cancelled`).
//!
//! On daemon start the journal is replayed: any job whose last record is
//! not terminal (the daemon crashed mid-queue or mid-run) is re-queued
//! under its original id and spec. A torn final line — the signature of a
//! crash mid-append — is skipped, never fatal. Appends are flushed and
//! fsync'd per record; jobs are coarse-grained enough that durability is
//! worth the syscall.
//!
//! After replay the journal is [compacted](Journal::compact): terminal
//! records are dead weight, so the file is rewritten down to a `compacted`
//! watermark (preserving the id sequence) plus the still-pending jobs,
//! staged via `.tmp` + rename so a crash mid-compaction loses nothing.

use super::api::JobSpec;
use super::queue::JobId;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// What a replay found.
pub struct Replay {
    /// Jobs with no terminal record, in submission order: re-queue these.
    pub pending: Vec<(JobId, JobSpec)>,
    /// One past the largest id ever journaled (the next fresh id).
    pub next_id: JobId,
}

pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

impl Journal {
    /// Open (creating if absent) the journal for appending.
    pub fn open(path: &Path) -> Result<Journal> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(Journal { path: path.to_path_buf(), file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Replay an existing journal file (missing file = empty replay).
    pub fn replay(path: &Path) -> Result<Replay> {
        let mut pending: Vec<(JobId, JobSpec)> = Vec::new();
        let mut next_id: JobId = 1;
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(e).context("reading journal"),
        };
        for line in text.lines() {
            // A torn trailing line (crash mid-append) is expected: skip
            // anything unparseable instead of refusing to start.
            let Ok(ev) = Json::parse(line) else { continue };
            let Some(tag) = ev.get("ev").and_then(|t| t.as_str()) else { continue };
            if tag == "compacted" {
                // Watermark left by `compact`: terminal records (and with
                // them the largest id seen) were dropped, so the sequence
                // is carried forward explicitly. No "job" key on this one.
                if let Some(n) = ev.get("next").and_then(|v| v.as_f64()) {
                    next_id = next_id.max(n as JobId);
                }
                continue;
            }
            let Some(id) = ev.get("job").and_then(|j| j.as_f64()).map(|v| v as JobId) else {
                continue;
            };
            next_id = next_id.max(id + 1);
            match tag {
                "submitted" => {
                    let Some(spec_json) = ev.get("spec") else { continue };
                    let Ok(spec) = JobSpec::from_json(spec_json) else { continue };
                    pending.push((id, spec));
                }
                "done" | "failed" | "cancelled" => {
                    pending.retain(|(p, _)| *p != id);
                }
                _ => {} // "started" keeps the job pending
            }
        }
        Ok(Replay { pending, next_id })
    }

    /// Rewrite the journal down to its live content: one `compacted`
    /// watermark record carrying `next_id`, then a `submitted` record per
    /// still-pending job. Staged to `<path>.tmp` and renamed over the
    /// original (the dataset atomic-finalize pattern) so a crash
    /// mid-compaction leaves the old journal intact. A missing journal is
    /// a no-op. Call after [`Journal::replay`], before [`Journal::open`].
    pub fn compact(path: &Path, replay: &Replay) -> Result<()> {
        if !path.exists() {
            return Ok(());
        }
        let mut text = Json::obj(vec![
            ("ev", Json::Str("compacted".into())),
            ("next", Json::Num(replay.next_id as f64)),
            ("ts", Json::Num(unix_now())),
        ])
        .dump();
        text.push('\n');
        for (id, spec) in &replay.pending {
            text.push_str(
                &Json::obj(vec![
                    ("ev", Json::Str("submitted".into())),
                    ("job", Json::Num(*id as f64)),
                    ("ts", Json::Num(unix_now())),
                    ("spec", spec.to_json()),
                ])
                .dump(),
            );
            text.push('\n');
        }
        let tmp = path.with_extension("jsonl.tmp");
        {
            let mut f =
                File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(text.as_bytes())?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))?;
        Ok(())
    }

    pub fn submitted(&self, id: JobId, spec: &JobSpec) {
        self.append(Json::obj(vec![
            ("ev", Json::Str("submitted".into())),
            ("job", Json::Num(id as f64)),
            ("ts", Json::Num(unix_now())),
            ("spec", spec.to_json()),
        ]));
    }

    pub fn started(&self, id: JobId) {
        self.event("started", id, None);
    }

    pub fn done(&self, id: JobId) {
        self.event("done", id, None);
    }

    pub fn failed(&self, id: JobId, error: &str) {
        self.event("failed", id, Some(("error", Json::Str(error.to_string()))));
    }

    pub fn cancelled(&self, id: JobId) {
        self.event("cancelled", id, None);
    }

    fn event(&self, tag: &str, id: JobId, extra: Option<(&str, Json)>) {
        let mut pairs = vec![
            ("ev", Json::Str(tag.to_string())),
            ("job", Json::Num(id as f64)),
            ("ts", Json::Num(unix_now())),
        ];
        if let Some(p) = extra {
            pairs.push(p);
        }
        self.append(Json::obj(pairs));
    }

    fn append(&self, ev: Json) {
        let mut line = ev.dump();
        line.push('\n');
        let mut f = self.file.lock().unwrap();
        // A journal write failing must not take down in-flight solves; the
        // daemon keeps serving and the operator sees the warning.
        if let Err(e) = f.write_all(line.as_bytes()).and_then(|()| f.sync_data()) {
            eprintln!("warning: journal append failed: {e}");
        }
    }
}

fn unix_now() -> f64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs_f64()).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn unique_journal() -> PathBuf {
        static COUNTER: AtomicUsize = AtomicUsize::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("skr_journal_{}_{n}.jsonl", std::process::id()))
    }

    #[test]
    fn missing_file_replays_empty() {
        let r = Journal::replay(Path::new("/nonexistent/skr/journal.jsonl")).unwrap();
        assert!(r.pending.is_empty());
        assert_eq!(r.next_id, 1);
    }

    #[test]
    fn lifecycle_replay_requeues_only_nonterminal() {
        let path = unique_journal();
        let j = Journal::open(&path).unwrap();
        let spec = JobSpec::default();
        j.submitted(1, &spec); // done → not requeued
        j.submitted(2, &spec); // started but never finished → requeued
        j.submitted(3, &spec); // never started → requeued
        j.submitted(4, &spec); // cancelled → not requeued
        j.started(1);
        j.done(1);
        j.started(2);
        j.cancelled(4);
        drop(j);
        let r = Journal::replay(&path).unwrap();
        let ids: Vec<JobId> = r.pending.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![2, 3]);
        assert_eq!(r.next_id, 5);
        assert_eq!(r.pending[0].1, spec);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_trailing_line_is_skipped() {
        let path = unique_journal();
        let j = Journal::open(&path).unwrap();
        j.submitted(1, &JobSpec::default());
        drop(j);
        // Simulate a crash mid-append: garbage partial record at the end.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"ev\":\"done\",\"jo").unwrap();
        drop(f);
        let r = Journal::replay(&path).unwrap();
        assert_eq!(r.pending.len(), 1);
        assert_eq!(r.pending[0].0, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replay_after_compaction_matches_and_appends_continue() {
        let path = unique_journal();
        let j = Journal::open(&path).unwrap();
        let spec = JobSpec::default();
        j.submitted(1, &spec);
        j.submitted(2, &spec);
        j.submitted(3, &spec);
        j.started(1);
        j.done(1);
        j.started(2);
        drop(j);
        let ids = |r: &Replay| r.pending.iter().map(|(id, _)| *id).collect::<Vec<JobId>>();
        let before = Journal::replay(&path).unwrap();
        let lines_before = std::fs::read_to_string(&path).unwrap().lines().count();
        Journal::compact(&path, &before).unwrap();
        let lines_after = std::fs::read_to_string(&path).unwrap().lines().count();
        assert!(lines_after < lines_before, "compaction must shrink: {lines_after} >= {lines_before}");
        let after = Journal::replay(&path).unwrap();
        assert_eq!(ids(&after), ids(&before));
        assert_eq!(after.next_id, before.next_id);
        assert_eq!(after.pending[0].1, spec);
        // Lifecycle appends keep working on the compacted file.
        let j = Journal::open(&path).unwrap();
        j.done(2);
        drop(j);
        let r = Journal::replay(&path).unwrap();
        assert_eq!(ids(&r), vec![3]);
        assert_eq!(r.next_id, 4);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_keeps_next_id_when_everything_is_terminal() {
        let path = unique_journal();
        let j = Journal::open(&path).unwrap();
        j.submitted(9, &JobSpec::default());
        j.started(9);
        j.done(9);
        drop(j);
        let before = Journal::replay(&path).unwrap();
        Journal::compact(&path, &before).unwrap();
        let r = Journal::replay(&path).unwrap();
        assert!(r.pending.is_empty());
        assert_eq!(r.next_id, 10, "the watermark must carry the id sequence");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compacting_a_missing_journal_is_a_noop() {
        let path = unique_journal();
        let replay = Journal::replay(&path).unwrap();
        Journal::compact(&path, &replay).unwrap();
        assert!(!path.exists());
    }

    #[test]
    fn failed_is_terminal() {
        let path = unique_journal();
        let j = Journal::open(&path).unwrap();
        j.submitted(7, &JobSpec::default());
        j.started(7);
        j.failed(7, "solver exploded");
        drop(j);
        let r = Journal::replay(&path).unwrap();
        assert!(r.pending.is_empty());
        assert_eq!(r.next_id, 8);
        let _ = std::fs::remove_file(&path);
    }
}
