//! Minimal HTTP/1.1 framing over `TcpStream` — just enough for the `skr
//! serve` JSON API and its thin CLI clients (std-only; one request per
//! connection, `Connection: close` semantics).
//!
//! Untrusted input discipline: the request line, header block and body are
//! all length-capped, and every parse failure surfaces as `Err` (the caller
//! answers 400) rather than a panic.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Largest accepted header block (request line + headers).
const MAX_HEAD: usize = 16 * 1024;
/// Largest accepted request/response body.
pub const MAX_BODY: usize = 4 * 1024 * 1024;
/// Fallback socket timeout applied by [`read_request`]/[`write_response`]
/// when the caller hasn't set one — a hung peer can no longer stall a
/// single-threaded accept loop forever.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    pub body: Vec<u8>,
}

impl Request {
    /// Split the path into non-empty segments: `/jobs/7` → `["jobs", "7"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers, e.g. `("Retry-After", "1")`.
    pub headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body: body.into_bytes(), headers: vec![] }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: body.into_bytes(),
            headers: vec![],
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

/// Read one request off the stream, capped at [`MAX_BODY`]. If the caller
/// hasn't set a read timeout, [`DEFAULT_IO_TIMEOUT`] is applied first so a
/// silent client can't hold the connection open indefinitely.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    read_request_capped(stream, MAX_BODY)
}

/// [`read_request`] with an explicit body cap — the dist shard-result
/// endpoint accepts far larger payloads than the 4 MB service default.
pub fn read_request_capped(stream: &mut TcpStream, max_body: usize) -> Result<Request> {
    if stream.read_timeout()?.is_none() {
        stream.set_read_timeout(Some(DEFAULT_IO_TIMEOUT))?;
    }
    let head = read_until_blank_line(stream)?;
    let head_text = std::str::from_utf8(&head).context("non-UTF8 request head")?;
    let mut lines = head_text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let target = parts.next().unwrap_or("");
    if method.is_empty() || !target.starts_with('/') {
        bail!("malformed request line {request_line:?}");
    }
    let path = target.split('?').next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    if content_length > max_body {
        bail!("body of {content_length} bytes exceeds cap {max_body}");
    }
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body).context("reading request body")?;
    Ok(Request { method, path, body })
}

/// Write a response and flush; always closes after one exchange. Applies
/// [`DEFAULT_IO_TIMEOUT`] if the caller hasn't set a write timeout.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    if stream.write_timeout()?.is_none() {
        stream.set_write_timeout(Some(DEFAULT_IO_TIMEOUT))?;
    }
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
        resp.status,
        resp.reason(),
        resp.content_type,
        resp.body.len()
    );
    for (name, value) in &resp.headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

fn read_until_blank_line(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut head = Vec::with_capacity(512);
    let mut byte = [0u8; 1];
    loop {
        let n = stream.read(&mut byte)?;
        if n == 0 {
            bail!("connection closed before request head completed");
        }
        head.push(byte[0]);
        if head.ends_with(b"\r\n\r\n") {
            head.truncate(head.len() - 4);
            return Ok(head);
        }
        if head.len() > MAX_HEAD {
            bail!("request head exceeds {MAX_HEAD} bytes");
        }
    }
}

/// Client side: one round-trip against `addr` (e.g. `127.0.0.1:7070`).
/// Returns `(status, body)`.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;

    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .context("no header/body separator in response")?;
    let head_text = std::str::from_utf8(&raw[..split]).context("non-UTF8 response head")?;
    let status: u16 = head_text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .context("no status code in response")?;
    let body = String::from_utf8_lossy(&raw[split + 4..]).into_owned();
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn one_shot_server(resp: Response) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/echo");
            assert_eq!(req.body, b"{\"x\":1}");
            write_response(&mut stream, &resp).unwrap();
        });
        addr
    }

    #[test]
    fn round_trip_request_response() {
        let addr = one_shot_server(
            Response::json(200, "{\"ok\":true}".to_string()).with_header("X-Test", "yes"),
        );
        let (status, body) = request(&addr, "POST", "/echo?q=1", Some("{\"x\":1}")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
    }

    #[test]
    fn segments_split() {
        let r = Request { method: "GET".into(), path: "/jobs/17".into(), body: vec![] };
        assert_eq!(r.segments(), vec!["jobs", "17"]);
        let r = Request { method: "GET".into(), path: "/".into(), body: vec![] };
        assert!(r.segments().is_empty());
    }

    #[test]
    fn default_timeouts_applied_but_caller_settings_win() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            // First connection: no caller timeout — read_request installs the
            // default so a mute client can't hang us.
            let (mut stream, _) = listener.accept().unwrap();
            assert!(stream.read_timeout().unwrap().is_none());
            let req = read_request(&mut stream).unwrap();
            assert_eq!(stream.read_timeout().unwrap(), Some(DEFAULT_IO_TIMEOUT));
            write_response(&mut stream, &Response::json(200, "{}".into())).unwrap();
            assert_eq!(stream.write_timeout().unwrap(), Some(DEFAULT_IO_TIMEOUT));
            assert_eq!(req.path, "/a");

            // Second connection: a tighter caller timeout must survive.
            let (mut stream, _) = listener.accept().unwrap();
            let tight = Duration::from_secs(10);
            stream.set_read_timeout(Some(tight)).unwrap();
            stream.set_write_timeout(Some(tight)).unwrap();
            read_request(&mut stream).unwrap();
            write_response(&mut stream, &Response::json(200, "{}".into())).unwrap();
            assert_eq!(stream.read_timeout().unwrap(), Some(tight));
            assert_eq!(stream.write_timeout().unwrap(), Some(tight));
        });
        request(&addr.to_string(), "GET", "/a", None).unwrap();
        request(&addr.to_string(), "GET", "/b", None).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn body_cap_is_configurable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            read_request_capped(&mut stream, 4).is_err()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello").unwrap();
        let _ = c.flush();
        assert!(handle.join().unwrap(), "5-byte body must exceed a 4-byte cap");
    }

    #[test]
    fn malformed_head_is_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            read_request(&mut stream).is_err()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"NOT A REQUEST\r\n\r\n").unwrap();
        drop(c);
        assert!(handle.join().unwrap());
    }
}
