//! The worker pool: each worker thread loops `take_next → run pipeline →
//! record outcome` until the queue drains. Pipeline runs go through
//! [`Pipeline::run_with`] with the job's [`RunControl`], so `DELETE
//! /jobs/:id` stops a run within one system solve and `GET /jobs/:id`
//! reports live progress; completed-job [`RunMetrics`] merge into the
//! service aggregate behind `GET /metrics`.

use super::queue::{JobState, Task};
use super::Service;
use crate::coordinator::{Cancelled, Pipeline};
use std::sync::Arc;

/// Run one worker until the queue reports drained.
pub fn run(svc: Arc<Service>) {
    while let Some(task) = svc.queue.take_next() {
        execute(&svc, task);
    }
}

fn execute(svc: &Service, task: Task) {
    let id = task.id;
    svc.journal.started(id);
    // The spec was validated at submit time, but a journal-replayed spec
    // could still be stale/bad — a config error is a job failure, not a
    // daemon crash.
    let result = task.spec.to_config().and_then(|cfg| Pipeline::new(cfg).run_with(&task.ctl));
    match result {
        Ok(res) => {
            svc.absorb_metrics(&res.metrics);
            let dataset = res.dataset.map(|d| d.dir.display().to_string());
            svc.journal.done(id);
            svc.queue.finish(id, JobState::Done, None, dataset);
            svc.note_outcome(JobState::Done);
        }
        Err(e) if e.downcast_ref::<Cancelled>().is_some() => {
            svc.journal.cancelled(id);
            svc.queue.finish(id, JobState::Cancelled, None, None);
            svc.note_outcome(JobState::Cancelled);
        }
        Err(e) => {
            let msg = format!("{e:#}");
            svc.journal.failed(id, &msg);
            svc.queue.finish(id, JobState::Failed, Some(msg), None);
            svc.note_outcome(JobState::Failed);
        }
    }
}
