//! `skr` — CLI for the SKR data-generation framework.
//!
//! Subcommands:
//! * `generate`  — run the pipeline and export an `.npy` dataset
//! * `compare`   — SKR vs GMRES on one configuration (quick speedup readout)
//! * `table1`    — reproduce the paper's headline Table 1
//! * `tables`    — reproduce the per-family sweep tables (3–30)
//! * `ablation`  — reproduce Table 2 (sort vs no-sort + δ)
//! * `figures`   — emit data series for Figs 1/4/5/7–13
//! * `parallel`  — reproduce Tables 31/32 (threaded/block variants)
//! * `train`     — train the FNO on a generated dataset via the PJRT runtime
//! * `validate`  — reproduce Table 33 (dataset-validity experiment)
//! * `bench`     — deterministic perf benchmarks + BENCH_*.json regression gate
//! * `report`    — aggregate a `--trace-out` JSONL trace into a summary
//! * `serve`     — resident job-queue daemon with an HTTP/JSON API
//! * `submit` / `jobs` / `status` / `cancel` — thin clients for `serve`
//! * `coordinate` — plan a run and hand out shard leases to remote workers
//! * `work`      — join a coordinator and solve shard leases

use skr::coordinator::{Pipeline, PipelineConfig};
use skr::harness;
use skr::service;
use skr::util::args::Args;
use skr::util::json::Json;

fn main() {
    let args = Args::from_env();
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "compare" => harness::compare::run(&args),
        "table1" => harness::table1::run(&args),
        "tables" => harness::sweeps::run(&args),
        "ablation" => harness::ablation::run(&args),
        "figures" => harness::figures::run(&args),
        "parallel" => harness::parallel::run(&args),
        "train" => harness::train::run(&args),
        "validate" => harness::validate::run(&args),
        "bench" => skr::bench::run(&args),
        "report" => skr::obs::report::run(&args),
        "serve" => service::serve(&service::ServeConfig::from_args(&args)),
        "coordinate" => {
            skr::dist::coordinate(&skr::dist::CoordinateConfig::from_args(&args)).map(|_| ())
        }
        "work" => skr::dist::WorkerConfig::from_args(&args).and_then(|cfg| skr::dist::work(&cfg)),
        "submit" => cmd_submit(&args),
        "jobs" => cmd_jobs(&args),
        "status" => cmd_status(&args),
        "cancel" => cmd_cancel(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let mut cfg = PipelineConfig::from_args(args)?;
    if cfg.out_dir.is_none() {
        cfg.out_dir = Some(std::path::PathBuf::from(format!(
            "results/dataset_{}_{}",
            cfg.family.label().to_lowercase(),
            cfg.count
        )));
    }
    let pipe = Pipeline::new(cfg);
    let r = pipe.run()?;
    let m = &r.metrics;
    println!(
        "family={} engine={} precond={} sort={} count={} n={}",
        pipe.config().family.label(),
        pipe.config().engine.label(),
        pipe.config().precond.label(),
        pipe.config().sort.label(),
        m.systems,
        pipe.family().num_unknowns(),
    );
    println!(
        "gen {:.3}s  sort {:.3}s  solve {:.3}s (mean {:.4}s, {:.1} iters/system)  wall {:.3}s",
        m.gen_seconds,
        m.sort_seconds,
        m.solve_seconds,
        m.mean_time(),
        m.mean_iters(),
        m.wall_seconds
    );
    println!(
        "residual: worst {:.3e}  mean {:.3e}",
        m.rel_residual_worst,
        m.mean_rel_residual()
    );
    println!(
        "reuse: sparsity {}/{}  symbolic {}/{}  workspace {}/{}",
        m.sparsity_reuse,
        m.systems,
        m.symbolic_reuse,
        m.systems,
        m.workspace_reuse,
        m.systems
    );
    println!(
        "ops: matvecs {}  precond {}  ortho_flops {}  recycle carry/reseed/harvest {}/{}/{}",
        m.counters.matvecs,
        m.counters.precond_applies,
        m.counters.ortho_flops,
        m.counters.recycle_carries,
        m.counters.recycle_reseeds,
        m.counters.harvests
    );
    if m.max_iter_hits > 0 {
        println!("WARNING: {} systems hit the iteration cap", m.max_iter_hits);
    }
    if m.breakdowns > 0 {
        println!("WARNING: {} systems ended in breakdown", m.breakdowns);
    }
    if let Some(ds) = &r.dataset {
        println!("dataset: {} ({} samples)", ds.dir.display(), ds.count);
    }
    if let Some(trace) = &pipe.config().trace_out {
        println!("trace: {} (inspect with `skr report {}`)", trace.display(), trace.display());
    }
    if pipe.config().strict && (m.max_iter_hits > 0 || m.breakdowns > 0) {
        anyhow::bail!(
            "--strict: {} max-iter hits, {} breakdowns",
            m.max_iter_hits,
            m.breakdowns
        );
    }
    Ok(())
}

fn service_addr(args: &Args) -> String {
    args.str_or("addr", "127.0.0.1:7070")
}

/// One API round-trip; non-2xx surfaces the server's error body.
fn api_call(args: &Args, method: &str, path: &str, body: Option<&str>) -> anyhow::Result<Json> {
    let addr = service_addr(args);
    let (status, text) = skr::service::http::request(&addr, method, path, body)?;
    let json = Json::parse(&text).unwrap_or(Json::Str(text.clone()));
    if !(200..300).contains(&status) {
        let msg = json.get("error").and_then(|e| e.as_str()).unwrap_or(&text);
        anyhow::bail!("{addr} answered {status}: {msg}");
    }
    Ok(json)
}

fn job_id_arg(args: &Args) -> anyhow::Result<u64> {
    args.positional()
        .first()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("usage: skr status|cancel <job-id> [--addr HOST:PORT]"))
}

fn cmd_submit(args: &Args) -> anyhow::Result<()> {
    let spec = service::JobSpec::from_args(args);
    let resp = api_call(args, "POST", "/jobs", Some(&spec.to_json().dump()))?;
    let id = resp.get("id").and_then(|v| v.as_usize()).unwrap_or(0);
    println!("job {id} queued ({} {} count={})", spec.family, spec.engine, spec.count);
    println!("poll with: skr status {id} --addr {}", service_addr(args));
    Ok(())
}

fn cmd_jobs(args: &Args) -> anyhow::Result<()> {
    let resp = api_call(args, "GET", "/jobs", None)?;
    let jobs = resp.get("jobs").and_then(|j| j.as_arr()).unwrap_or(&[]);
    println!(
        "{} job(s), {} queued, {} running{}",
        jobs.len(),
        resp.get("queued").and_then(|v| v.as_usize()).unwrap_or(0),
        resp.get("running").and_then(|v| v.as_usize()).unwrap_or(0),
        if resp.get("draining") == Some(&Json::Bool(true)) { " (draining)" } else { "" },
    );
    for j in jobs {
        print_job_line(j);
    }
    Ok(())
}

fn cmd_status(args: &Args) -> anyhow::Result<()> {
    let id = job_id_arg(args)?;
    let resp = api_call(args, "GET", &format!("/jobs/{id}"), None)?;
    print_job_line(&resp);
    if let Some(err) = resp.get("error").and_then(|e| e.as_str()) {
        println!("  error: {err}");
    }
    if let Some(ds) = resp.get("dataset").and_then(|d| d.as_str()) {
        println!("  dataset: {ds}");
    }
    Ok(())
}

fn cmd_cancel(args: &Args) -> anyhow::Result<()> {
    let id = job_id_arg(args)?;
    let resp = api_call(args, "DELETE", &format!("/jobs/{id}"), None)?;
    println!(
        "job {id}: {}",
        resp.get("state").and_then(|s| s.as_str()).unwrap_or("unknown")
    );
    Ok(())
}

fn print_job_line(j: &Json) {
    let get_n = |outer: &Json, key: &str| outer.get(key).and_then(|v| v.as_usize()).unwrap_or(0);
    let progress = j.get("progress").cloned().unwrap_or(Json::Null);
    let spec = j.get("spec").cloned().unwrap_or(Json::Null);
    println!(
        "job {:>4}  {:<10} {}/{} systems  family={} n={} seed={}  reuse s/y/w {}/{}/{}",
        get_n(j, "id"),
        j.get("state").and_then(|s| s.as_str()).unwrap_or("?"),
        get_n(&progress, "done"),
        get_n(&progress, "total"),
        spec.get("family").and_then(|s| s.as_str()).unwrap_or("?"),
        get_n(&spec, "n"),
        get_n(&spec, "seed"),
        get_n(&progress, "sparsity_reuse"),
        get_n(&progress, "symbolic_reuse"),
        get_n(&progress, "workspace_reuse"),
    );
}

fn print_help() {
    println!(
        "skr — Sorting + Krylov Recycling data generation for neural operators

USAGE: skr <command> [--key value ...]

COMMANDS
  generate   run the pipeline, export .npy dataset
             --family darcy|thermal|poisson|helmholtz --n 2500 --count 64
             --engine skr|gmres --precond none|jacobi|bjacobi|sor|asm|icc|ilu
             --sort greedy|none|grouped|hilbert|shuffle --tol 1e-8
             --threads 1 --out DIR --seed 0
             --trace-out t.jsonl  write a JSONL event trace (spans, per-system
                                  solves, per-cycle residuals, worker rollups)
             --progress           live progress line (systems/sec, ETA) on stderr
             --strict             exit nonzero if any system hit the iteration
                                  cap or broke down
  compare    SKR vs GMRES quick speedup readout (same flags; --trace-out P
             writes per-engine traces P.gmres.jsonl / P.skr.jsonl)
  table1     paper Table 1 (headline speedups)         [--full]
  tables     paper Tables 3..30 sweeps                 [--family F] [--full]
  ablation   paper Table 2 (sort ablation + delta)     [--full]
  figures    paper Figs 1,4-5,7-13 data series         [--fig all|conv|similarity|sortpairs|f11|f12|f13]
  parallel   paper Tables 31/32 (parallel + block)     [--threads N]
  train      train the FNO on a dataset via PJRT       --data DIR [--steps N]
  validate   paper Table 33 (dataset validity)         [--full]
  report     aggregate a trace: skr report t.jsonl [--prometheus]
             (percentile solve times, iteration histogram, per-worker
             timeline/utilization, backpressure totals)

BENCH (see README \"Benchmarking & regression gating\")
  bench      run named workloads under both engines; median/IQR wall-clock
             plus deterministic op counters (matvecs, precond applies,
             ortho flops, recycle installs, harvests) that are bit-stable
             across repeats and machines
             --quick              small CI suite instead of the full one
             --workload SUBSTR    filter workloads by name
             --manifest FILE      custom workload manifest (json)
             --warmup N --runs N  override the repetition protocol
             --out BENCH_rev.json [--rev label]   save a baseline
             --check FILE         replay FILE's workloads and fail on any
                                  counter increase; time gated by
                                  --max-regress 5% unless --counters-only
             --compare A.json B.json   per-workload delta table
             (each result carries the recycled-vs-GMRES speedup ratio)

SERVICE (see README \"Running as a service\")
  serve      resident job-queue daemon with an HTTP/JSON API
             --host 127.0.0.1 --port 7070 (0 = ephemeral)
             --workers 1          concurrent jobs
             --queue-cap 64       pending backlog before 429
             --state-dir results/service   journal.jsonl location
             endpoints: POST/GET /jobs, GET/DELETE /jobs/:id,
             GET /metrics, GET /healthz, POST /shutdown (graceful drain)
  submit     enqueue a generation job (same flags as generate, plus --addr)
             skr submit --addr 127.0.0.1:7070 --family darcy --count 64 --out DIR
  jobs       list jobs + queue state          [--addr HOST:PORT]
  status     one job incl. live progress:     skr status <id> [--addr ...]
  cancel     cancel a queued or running job:  skr cancel <id> [--addr ...]

DIST (see README \"Distributed generation\")
  coordinate plan a run (sort + shard exactly like generate) and serve
             shard leases to workers; merges results into one dataset that
             is byte-identical to single-node `generate --threads <shards>`
             --host 127.0.0.1 --port 7171 (0 = ephemeral)
             --shards N           shard count (default: --threads)
             --lease-ms 30000     lease lifetime without a heartbeat
             --max-attempts 3     grants per shard before DEGRADED flag
             --backoff-ms 500     requeue backoff base (doubles per attempt)
             plus every generate flag (--family, --count, --seed, --out, ...)
             endpoints: GET /plan, POST /lease, POST /heartbeat,
             POST /shards/:id/result, GET /metrics, GET /healthz
  work       join a coordinator and solve shard leases until the run ends
             --join HOST:PORT     coordinator address (required)
             --name w<pid>        worker name for leases/heartbeats
"
    );
}
