//! `skr` — CLI for the SKR data-generation framework.
//!
//! Subcommands:
//! * `generate`  — run the pipeline and export an `.npy` dataset
//! * `compare`   — SKR vs GMRES on one configuration (quick speedup readout)
//! * `table1`    — reproduce the paper's headline Table 1
//! * `tables`    — reproduce the per-family sweep tables (3–30)
//! * `ablation`  — reproduce Table 2 (sort vs no-sort + δ)
//! * `figures`   — emit data series for Figs 1/4/5/7–13
//! * `parallel`  — reproduce Tables 31/32 (threaded/block variants)
//! * `train`     — train the FNO on a generated dataset via the PJRT runtime
//! * `validate`  — reproduce Table 33 (dataset-validity experiment)
//! * `report`    — aggregate a `--trace-out` JSONL trace into a summary

use skr::coordinator::{Pipeline, PipelineConfig};
use skr::harness;
use skr::util::args::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "compare" => harness::compare::run(&args),
        "table1" => harness::table1::run(&args),
        "tables" => harness::sweeps::run(&args),
        "ablation" => harness::ablation::run(&args),
        "figures" => harness::figures::run(&args),
        "parallel" => harness::parallel::run(&args),
        "train" => harness::train::run(&args),
        "validate" => harness::validate::run(&args),
        "report" => skr::obs::report::run(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let mut cfg = PipelineConfig::from_args(args)?;
    if cfg.out_dir.is_none() {
        cfg.out_dir = Some(std::path::PathBuf::from(format!(
            "results/dataset_{}_{}",
            cfg.family.label().to_lowercase(),
            cfg.count
        )));
    }
    let pipe = Pipeline::new(cfg);
    let r = pipe.run()?;
    let m = &r.metrics;
    println!(
        "family={} engine={} precond={} sort={} count={} n={}",
        pipe.config().family.label(),
        pipe.config().engine.label(),
        pipe.config().precond.label(),
        pipe.config().sort.label(),
        m.systems,
        pipe.family().num_unknowns(),
    );
    println!(
        "gen {:.3}s  sort {:.3}s  solve {:.3}s (mean {:.4}s, {:.1} iters/system)  wall {:.3}s",
        m.gen_seconds,
        m.sort_seconds,
        m.solve_seconds,
        m.mean_time(),
        m.mean_iters(),
        m.wall_seconds
    );
    println!(
        "residual: worst {:.3e}  mean {:.3e}",
        m.rel_residual_worst,
        m.mean_rel_residual()
    );
    println!(
        "reuse: sparsity {}/{}  symbolic {}/{}  workspace {}/{}",
        m.sparsity_reuse,
        m.systems,
        m.symbolic_reuse,
        m.systems,
        m.workspace_reuse,
        m.systems
    );
    if m.max_iter_hits > 0 {
        println!("WARNING: {} systems hit the iteration cap", m.max_iter_hits);
    }
    if m.breakdowns > 0 {
        println!("WARNING: {} systems ended in breakdown", m.breakdowns);
    }
    if let Some(ds) = &r.dataset {
        println!("dataset: {} ({} samples)", ds.dir.display(), ds.count);
    }
    if let Some(trace) = &pipe.config().trace_out {
        println!("trace: {} (inspect with `skr report {}`)", trace.display(), trace.display());
    }
    if pipe.config().strict && (m.max_iter_hits > 0 || m.breakdowns > 0) {
        anyhow::bail!(
            "--strict: {} max-iter hits, {} breakdowns",
            m.max_iter_hits,
            m.breakdowns
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "skr — Sorting + Krylov Recycling data generation for neural operators

USAGE: skr <command> [--key value ...]

COMMANDS
  generate   run the pipeline, export .npy dataset
             --family darcy|thermal|poisson|helmholtz --n 2500 --count 64
             --engine skr|gmres --precond none|jacobi|bjacobi|sor|asm|icc|ilu
             --sort greedy|none|grouped|hilbert|shuffle --tol 1e-8
             --threads 1 --out DIR --seed 0
             --trace-out t.jsonl  write a JSONL event trace (spans, per-system
                                  solves, per-cycle residuals, worker rollups)
             --progress           live progress line (systems/sec, ETA) on stderr
             --strict             exit nonzero if any system hit the iteration
                                  cap or broke down
  compare    SKR vs GMRES quick speedup readout (same flags; --trace-out P
             writes per-engine traces P.gmres.jsonl / P.skr.jsonl)
  table1     paper Table 1 (headline speedups)         [--full]
  tables     paper Tables 3..30 sweeps                 [--family F] [--full]
  ablation   paper Table 2 (sort ablation + delta)     [--full]
  figures    paper Figs 1,4-5,7-13 data series         [--fig all|conv|similarity|sortpairs|f11|f12|f13]
  parallel   paper Tables 31/32 (parallel + block)     [--threads N]
  train      train the FNO on a dataset via PJRT       --data DIR [--steps N]
  validate   paper Table 33 (dataset validity)         [--full]
  report     aggregate a trace: skr report t.jsonl [--prometheus]
             (percentile solve times, iteration histogram, per-worker
             timeline/utilization, backpressure totals)
"
    );
}
