//! Compressed-sparse-row matrices — the discretised PDE operators.
//!
//! All solver/preconditioner hot loops run over this layout; `matvec_into`
//! is the single most executed kernel in the repository.

use anyhow::{bail, Result};

/// CSR sparse matrix with `f64` entries.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    /// Row start offsets, length `nrows + 1`.
    pub row_ptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub col_idx: Vec<usize>,
    /// Nonzero values, aligned with `col_idx`.
    pub vals: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed, entries
    /// that sum to exactly zero are kept (structural nonzeros matter for ILU).
    pub fn from_triplets(nrows: usize, ncols: usize, triplets: &[(usize, usize, f64)]) -> Csr {
        let mut entries: Vec<(usize, usize, f64)> = triplets.to_vec();
        entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
        // merge duplicates
        let mut merged: Vec<(usize, usize, f64)> = Vec::with_capacity(entries.len());
        for (r, c, v) in entries {
            assert!(r < nrows && c < ncols, "triplet ({r},{c}) out of bounds");
            match merged.last_mut() {
                Some(last) if last.0 == r && last.1 == c => last.2 += v,
                _ => merged.push((r, c, v)),
            }
        }
        let mut row_ptr = vec![0usize; nrows + 1];
        for &(r, _, _) in &merged {
            row_ptr[r + 1] += 1;
        }
        for i in 0..nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let col_idx = merged.iter().map(|&(_, c, _)| c).collect();
        let vals = merged.iter().map(|&(_, _, v)| v).collect();
        Csr { nrows, ncols, row_ptr, col_idx, vals }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Csr {
        Csr::from_triplets(n, n, &(0..n).map(|i| (i, i, 1.0)).collect::<Vec<_>>())
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// All stored values in row-major CSR order.
    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    /// Column indices aligned with [`Csr::values`].
    pub fn col_indices(&self) -> &[usize] {
        &self.col_idx
    }

    /// Row `i` as (cols, vals) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[a..b], &self.vals[a..b])
    }

    /// Entry lookup (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// y = A x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.nrows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into a caller-provided buffer. Hot path.
    #[inline]
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        for i in 0..self.nrows {
            let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
            let mut s = 0.0;
            // Indexed loop over the row; bounds checks hoist since a..b are
            // monotone and col_idx entries were validated at construction.
            for k in a..b {
                s += self.vals[k] * x[self.col_idx[k]];
            }
            y[i] = s;
        }
    }

    /// y = Aᵀ x.
    pub fn matvec_transpose(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.ncols];
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let xi = x[i];
            for (&c, &v) in cols.iter().zip(vals) {
                y[c] += v * xi;
            }
        }
        y
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Csr {
        let mut trips = Vec::with_capacity(self.nnz());
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trips.push((c, i, v));
            }
        }
        Csr::from_triplets(self.ncols, self.nrows, &trips)
    }

    /// Main diagonal (zeros where absent).
    pub fn diag(&self) -> Vec<f64> {
        (0..self.nrows.min(self.ncols)).map(|i| self.get(i, i)).collect()
    }

    /// Symmetric part ½(A + Aᵀ) (used by the ICC fallback on nonsymmetric A).
    pub fn symmetric_part(&self) -> Csr {
        let mut trips = Vec::with_capacity(2 * self.nnz());
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trips.push((i, c, 0.5 * v));
                trips.push((c, i, 0.5 * v));
            }
        }
        Csr::from_triplets(self.nrows, self.ncols, &trips)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.vals.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max relative asymmetry |a_ij - a_ji| / ||A||_F — cheap symmetry probe.
    pub fn asymmetry(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                worst = worst.max((v - self.get(c, i)).abs());
            }
        }
        let f = self.fro_norm();
        if f == 0.0 {
            0.0
        } else {
            worst / f
        }
    }

    /// Scale all values.
    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.vals {
            *v *= alpha;
        }
    }

    /// A + alpha * I (square matrices). Keeps CSR invariants.
    pub fn add_diag(&self, alpha: f64) -> Csr {
        assert_eq!(self.nrows, self.ncols);
        let mut trips: Vec<(usize, usize, f64)> = Vec::with_capacity(self.nnz() + self.nrows);
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trips.push((i, c, v));
            }
            trips.push((i, i, alpha));
        }
        Csr::from_triplets(self.nrows, self.ncols, &trips)
    }

    /// Validate structural invariants (used by property tests).
    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.nrows + 1 {
            bail!("row_ptr length");
        }
        if *self.row_ptr.last().unwrap() != self.vals.len() || self.col_idx.len() != self.vals.len() {
            bail!("ptr/idx/vals mismatch");
        }
        for i in 0..self.nrows {
            if self.row_ptr[i] > self.row_ptr[i + 1] {
                bail!("row_ptr not monotone at {i}");
            }
            let (cols, _) = self.row(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    bail!("row {i} columns not strictly increasing");
                }
            }
            if let Some(&c) = cols.last() {
                if c >= self.ncols {
                    bail!("column out of range in row {i}");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [ 4 -1  0 ]
        // [-1  4 -1 ]
        // [ 0 -1  4 ]
        Csr::from_triplets(
            3,
            3,
            &[(0, 0, 4.0), (0, 1, -1.0), (1, 0, -1.0), (1, 1, 4.0), (1, 2, -1.0), (2, 1, -1.0), (2, 2, 4.0)],
        )
    }

    #[test]
    fn triplets_merge_and_sort() {
        let a = Csr::from_triplets(2, 2, &[(1, 1, 2.0), (0, 0, 1.0), (1, 1, 3.0)]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get(1, 1), 5.0);
        a.validate().unwrap();
    }

    #[test]
    fn matvec_matches_dense() {
        let a = sample();
        let y = a.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![2.0, 4.0, 10.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = sample();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn matvec_transpose_consistent() {
        let a = Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)]);
        let x = [1.0, -1.0];
        let y1 = a.matvec_transpose(&x);
        let y2 = a.transpose().matvec(&x);
        assert_eq!(y1, y2);
    }

    #[test]
    fn symmetric_part_is_symmetric() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 1, 3.0)]);
        let s = a.symmetric_part();
        assert_eq!(s.get(0, 1), 1.0);
        assert_eq!(s.get(1, 0), 1.0);
        assert!(s.asymmetry() < 1e-15);
    }

    #[test]
    fn diag_and_add_diag() {
        let a = sample();
        assert_eq!(a.diag(), vec![4.0, 4.0, 4.0]);
        let b = a.add_diag(1.0);
        assert_eq!(b.diag(), vec![5.0, 5.0, 5.0]);
        b.validate().unwrap();
    }
}
