//! Compressed-sparse-row matrices — the discretised PDE operators.
//!
//! A matrix is a `{ sparsity: Arc<Sparsity>, vals: Vec<f64> }` pair: the
//! structure half is shared across every system of a generation sequence
//! (same grid, same stencil), the value half is per-system. All
//! solver/preconditioner hot loops run over this layout; `matvec_into` is
//! the single most executed kernel in the repository.

use super::sparsity::Sparsity;
use anyhow::{bail, Result};
use std::sync::Arc;

/// CSR sparse matrix with `f64` entries and shared structure.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    sparsity: Arc<Sparsity>,
    vals: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed, entries
    /// that sum to exactly zero are kept (structural nonzeros matter for ILU).
    /// Compatibility constructor — prefer [`Sparsity::from_pattern`] +
    /// [`Csr::with_values`] when many systems share one structure.
    pub fn from_triplets(nrows: usize, ncols: usize, triplets: &[(usize, usize, f64)]) -> Csr {
        let mut entries: Vec<(usize, usize, f64)> = triplets.to_vec();
        entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
        // Merge duplicates in place: `w` is the write cursor over the sorted run.
        let mut w = 0usize;
        for k in 0..entries.len() {
            let (r, c, v) = entries[k];
            assert!(r < nrows && c < ncols, "triplet ({r},{c}) out of bounds");
            if w > 0 && entries[w - 1].0 == r && entries[w - 1].1 == c {
                entries[w - 1].2 += v;
            } else {
                entries[w] = (r, c, v);
                w += 1;
            }
        }
        entries.truncate(w);
        let mut row_ptr = vec![0usize; nrows + 1];
        for &(r, _, _) in &entries {
            row_ptr[r + 1] += 1;
        }
        for i in 0..nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut col_idx = Vec::with_capacity(w);
        let mut vals = Vec::with_capacity(w);
        for &(_, c, v) in &entries {
            col_idx.push(c);
            vals.push(v);
        }
        let sparsity = Arc::new(Sparsity::from_parts(nrows, ncols, row_ptr, col_idx));
        Csr { sparsity, vals }
    }

    /// Stamp values onto a shared structure. `vals` must be in CSR order
    /// (row-major, columns sorted — i.e. aligned with `sparsity.col_idx`).
    pub fn with_values(sparsity: Arc<Sparsity>, vals: Vec<f64>) -> Result<Csr> {
        if vals.len() != sparsity.nnz() {
            bail!("with_values: {} values for a structure with {} nonzeros", vals.len(), sparsity.nnz());
        }
        Ok(Csr { sparsity, vals })
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Csr {
        Csr::from_triplets(n, n, &(0..n).map(|i| (i, i, 1.0)).collect::<Vec<_>>())
    }

    /// The shared structure half.
    pub fn sparsity(&self) -> &Arc<Sparsity> {
        &self.sparsity
    }

    pub fn nrows(&self) -> usize {
        self.sparsity.nrows()
    }

    pub fn ncols(&self) -> usize {
        self.sparsity.ncols()
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// All stored values in row-major CSR order.
    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    /// Mutable view of the stored values (structure stays shared).
    pub fn values_mut(&mut self) -> &mut [f64] {
        &mut self.vals
    }

    /// Structure and mutable values together (split borrow for factor loops).
    pub fn parts_mut(&mut self) -> (&Sparsity, &mut [f64]) {
        (&self.sparsity, &mut self.vals)
    }

    /// Column indices aligned with [`Csr::values`].
    pub fn col_indices(&self) -> &[usize] {
        &self.sparsity.col_idx
    }

    /// Row start offsets, length `nrows + 1`.
    pub fn row_offsets(&self) -> &[usize] {
        &self.sparsity.row_ptr
    }

    /// Row `i` as (cols, vals) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.sparsity.row_ptr[i], self.sparsity.row_ptr[i + 1]);
        (&self.sparsity.col_idx[a..b], &self.vals[a..b])
    }

    /// Entry lookup (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// y = A x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.nrows()];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into a caller-provided buffer. Hot path.
    #[inline]
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols());
        debug_assert_eq!(y.len(), self.nrows());
        let row_ptr = &self.sparsity.row_ptr;
        let col_idx = &self.sparsity.col_idx;
        let vals = &self.vals;
        for (i, yi) in y.iter_mut().enumerate() {
            let (a, b) = (row_ptr[i], row_ptr[i + 1]);
            let mut s = 0.0;
            // Indexed loop over the row; bounds checks hoist since a..b are
            // monotone and col_idx entries were validated at construction.
            for k in a..b {
                s += vals[k] * x[col_idx[k]];
            }
            *yi = s;
        }
    }

    /// y = Aᵀ x.
    pub fn matvec_transpose(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.ncols()];
        for i in 0..self.nrows() {
            let (cols, vals) = self.row(i);
            let xi = x[i];
            for (&c, &v) in cols.iter().zip(vals) {
                y[c] += v * xi;
            }
        }
        y
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Csr {
        let mut trips = Vec::with_capacity(self.nnz());
        for i in 0..self.nrows() {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trips.push((c, i, v));
            }
        }
        Csr::from_triplets(self.ncols(), self.nrows(), &trips)
    }

    /// Main diagonal (zeros where absent).
    pub fn diag(&self) -> Vec<f64> {
        (0..self.nrows().min(self.ncols())).map(|i| self.get(i, i)).collect()
    }

    /// Symmetric part ½(A + Aᵀ) (used by the ICC fallback on nonsymmetric A).
    pub fn symmetric_part(&self) -> Csr {
        let mut trips = Vec::with_capacity(2 * self.nnz());
        for i in 0..self.nrows() {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trips.push((i, c, 0.5 * v));
                trips.push((c, i, 0.5 * v));
            }
        }
        Csr::from_triplets(self.nrows(), self.ncols(), &trips)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.vals.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max relative asymmetry |a_ij - a_ji| / ||A||_F — cheap symmetry probe.
    pub fn asymmetry(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..self.nrows() {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                worst = worst.max((v - self.get(c, i)).abs());
            }
        }
        let f = self.fro_norm();
        if f == 0.0 {
            0.0
        } else {
            worst / f
        }
    }

    /// Scale all values.
    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.vals {
            *v *= alpha;
        }
    }

    /// A + alpha * I (square matrices). Keeps CSR invariants.
    pub fn add_diag(&self, alpha: f64) -> Csr {
        assert_eq!(self.nrows(), self.ncols());
        let mut trips: Vec<(usize, usize, f64)> = Vec::with_capacity(self.nnz() + self.nrows());
        for i in 0..self.nrows() {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                trips.push((i, c, v));
            }
            trips.push((i, i, alpha));
        }
        Csr::from_triplets(self.nrows(), self.ncols(), &trips)
    }

    /// Validate structural invariants (used by property tests).
    pub fn validate(&self) -> Result<()> {
        self.sparsity.validate()?;
        if self.vals.len() != self.sparsity.nnz() {
            bail!("vals/structure mismatch");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [ 4 -1  0 ]
        // [-1  4 -1 ]
        // [ 0 -1  4 ]
        Csr::from_triplets(
            3,
            3,
            &[(0, 0, 4.0), (0, 1, -1.0), (1, 0, -1.0), (1, 1, 4.0), (1, 2, -1.0), (2, 1, -1.0), (2, 2, 4.0)],
        )
    }

    #[test]
    fn triplets_merge_and_sort() {
        let a = Csr::from_triplets(2, 2, &[(1, 1, 2.0), (0, 0, 1.0), (1, 1, 3.0)]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get(1, 1), 5.0);
        a.validate().unwrap();
    }

    #[test]
    fn with_values_matches_from_triplets() {
        let trips = [(0, 0, 4.0), (0, 1, -1.0), (1, 0, -1.0), (1, 1, 4.0)];
        let a = Csr::from_triplets(2, 2, &trips);
        let pattern: Vec<(usize, usize)> = trips.iter().map(|&(r, c, _)| (r, c)).collect();
        let sp = Arc::new(Sparsity::from_pattern(2, 2, &pattern));
        let mut vals = vec![0.0; sp.nnz()];
        for &(r, c, v) in &trips {
            vals[sp.pos(r, c).unwrap()] = v;
        }
        let b = Csr::with_values(sp, vals).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn with_values_rejects_wrong_length() {
        let sp = Arc::new(Sparsity::from_pattern(2, 2, &[(0, 0), (1, 1)]));
        assert!(Csr::with_values(sp, vec![1.0]).is_err());
    }

    #[test]
    fn clone_shares_structure() {
        let a = sample();
        let b = a.clone();
        assert!(Arc::ptr_eq(a.sparsity(), b.sparsity()));
    }

    #[test]
    fn matvec_matches_dense() {
        let a = sample();
        let y = a.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![2.0, 4.0, 10.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = sample();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn matvec_transpose_consistent() {
        let a = Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)]);
        let x = [1.0, -1.0];
        let y1 = a.matvec_transpose(&x);
        let y2 = a.transpose().matvec(&x);
        assert_eq!(y1, y2);
    }

    #[test]
    fn symmetric_part_is_symmetric() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 1, 3.0)]);
        let s = a.symmetric_part();
        assert_eq!(s.get(0, 1), 1.0);
        assert_eq!(s.get(1, 0), 1.0);
        assert!(s.asymmetry() < 1e-15);
    }

    #[test]
    fn diag_and_add_diag() {
        let a = sample();
        assert_eq!(a.diag(), vec![4.0, 4.0, 4.0]);
        let b = a.add_diag(1.0);
        assert_eq!(b.diag(), vec![5.0, 5.0, 5.0]);
        b.validate().unwrap();
    }
}
