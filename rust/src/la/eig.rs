//! Dense nonsymmetric eigensolver: complex Hessenberg reduction followed by
//! explicitly-shifted (Wilkinson) QR iteration to Schur form, then eigenvector
//! back-substitution.
//!
//! GCRO-DR needs the `k` smallest-magnitude harmonic Ritz pairs of small
//! (m ≈ 30–80) nonsymmetric matrices each cycle; LAPACK is unavailable
//! offline, so this module implements the classic algorithm directly. The
//! explicit-shift variant is chosen over implicit bulge-chasing for
//! robustness and auditability at these sizes.

use super::c64::C64;
use super::zmat::ZMat;
use anyhow::{bail, Result};

/// Result of an eigendecomposition: `values[j]` pairs with column `j` of `vectors`.
#[derive(Debug, Clone)]
pub struct Eig {
    pub values: Vec<C64>,
    pub vectors: ZMat,
}

/// Complex Givens rotation zeroing `b` in `[a; b]`: returns (c, s, r) with
/// `[c, s; -conj(s), c] [a; b] = [r; 0]` and `c` real.
fn givens(a: C64, b: C64) -> (f64, C64, C64) {
    if b.norm_sqr() == 0.0 {
        return (1.0, C64::ZERO, a);
    }
    if a.norm_sqr() == 0.0 {
        let babs = b.abs();
        return (0.0, b.conj().scale(1.0 / babs), C64::real(babs));
    }
    let aabs = a.abs();
    let t = (a.norm_sqr() + b.norm_sqr()).sqrt();
    let c = aabs / t;
    let phase = a.scale(1.0 / aabs);
    let s = phase * b.conj().scale(1.0 / t);
    let r = phase.scale(t);
    (c, s, r)
}

/// Reduce `a` to upper Hessenberg form H = Qᴴ A Q via Householder; returns (H, Q).
fn hessenberg(a: &ZMat) -> (ZMat, ZMat) {
    let n = a.nrows;
    let mut h = a.clone();
    let mut q = ZMat::eye(n);
    for k in 0..n.saturating_sub(2) {
        // Householder vector from column k, rows k+1..n.
        let mut sigma = 0.0;
        for i in k + 1..n {
            sigma += h[(i, k)].norm_sqr();
        }
        if sigma == 0.0 {
            continue;
        }
        let x0 = h[(k + 1, k)];
        let alpha_mag = sigma.sqrt();
        let phase = if x0.norm_sqr() == 0.0 { C64::ONE } else { x0.scale(1.0 / x0.abs()) };
        let alpha = -phase.scale(alpha_mag);
        let mut v: Vec<C64> = (k + 1..n).map(|i| h[(i, k)]).collect();
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|z| z.norm_sqr()).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        let beta = 2.0 / vnorm2;
        // H ← (I - β v vᴴ) H
        for j in k..n {
            let mut s = C64::ZERO;
            for (t, i) in (k + 1..n).enumerate() {
                s += v[t].conj() * h[(i, j)];
            }
            s = s.scale(beta);
            for (t, i) in (k + 1..n).enumerate() {
                let d = v[t] * s;
                h[(i, j)] -= d;
            }
        }
        // H ← H (I - β v vᴴ)
        for i in 0..n {
            let mut s = C64::ZERO;
            for (t, j) in (k + 1..n).enumerate() {
                s += h[(i, j)] * v[t];
            }
            s = s.scale(beta);
            for (t, j) in (k + 1..n).enumerate() {
                let d = s * v[t].conj();
                h[(i, j)] -= d;
            }
        }
        // Q ← Q (I - β v vᴴ)
        for i in 0..n {
            let mut s = C64::ZERO;
            for (t, j) in (k + 1..n).enumerate() {
                s += q[(i, j)] * v[t];
            }
            s = s.scale(beta);
            for (t, j) in (k + 1..n).enumerate() {
                let d = s * v[t].conj();
                q[(i, j)] -= d;
            }
        }
        // Explicitly zero the annihilated entries.
        h[(k + 1, k)] = alpha;
        for i in k + 2..n {
            h[(i, k)] = C64::ZERO;
        }
    }
    (h, q)
}

/// Wilkinson shift from the trailing 2×2 of the active block.
fn wilkinson_shift(h: &ZMat, hi: usize) -> C64 {
    let a = h[(hi - 1, hi - 1)];
    let b = h[(hi - 1, hi)];
    let c = h[(hi, hi - 1)];
    let d = h[(hi, hi)];
    let tr2 = (a + d).scale(0.5);
    let det = a * d - b * c;
    let disc = (tr2 * tr2 - det).sqrt();
    let l1 = tr2 + disc;
    let l2 = tr2 - disc;
    if (l1 - d).norm_sqr() <= (l2 - d).norm_sqr() {
        l1
    } else {
        l2
    }
}

/// Schur decomposition A = Z T Zᴴ with T upper triangular.
pub fn schur(a: &ZMat) -> Result<(ZMat, ZMat)> {
    let n = a.nrows;
    assert_eq!(a.ncols, n);
    if n == 0 {
        return Ok((ZMat::zeros(0, 0), ZMat::zeros(0, 0)));
    }
    let (mut h, mut z) = hessenberg(a);
    let eps = f64::EPSILON;
    let max_total = 60 * n.max(1);
    let mut hi = n - 1;
    let mut iters_at_block = 0usize;
    let mut total = 0usize;
    while hi > 0 {
        // Deflate converged subdiagonals.
        let mut lo = hi;
        while lo > 0 {
            let s = h[(lo - 1, lo - 1)].abs() + h[(lo, lo)].abs();
            let s = if s == 0.0 { h.fro_norm() } else { s };
            if h[(lo, lo - 1)].abs() <= eps * s {
                h[(lo, lo - 1)] = C64::ZERO;
                break;
            }
            lo -= 1;
        }
        if lo == hi {
            hi -= 1;
            iters_at_block = 0;
            continue;
        }
        total += 1;
        iters_at_block += 1;
        if total > max_total {
            bail!("QR iteration failed to converge after {total} sweeps (n={n})");
        }
        // Shift: Wilkinson normally, exceptional after stagnation.
        let mu = if iters_at_block % 12 == 0 {
            let x = h[(hi, hi - 1)].abs() + if hi >= 2 { h[(hi - 1, hi - 2)].abs() } else { 0.0 };
            h[(hi, hi)] + C64::real(1.5 * x)
        } else {
            wilkinson_shift(&h, hi)
        };
        // Explicit shifted QR step on the active block [lo..=hi]:
        //   H - μI = G R ;  H ← R Gᴴ... (we apply rotations two-sided).
        for i in lo..=hi {
            h[(i, i)] -= mu;
        }
        let mut rots: Vec<(f64, C64)> = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            let (c, s, r) = givens(h[(i, i)], h[(i + 1, i)]);
            h[(i, i)] = r;
            h[(i + 1, i)] = C64::ZERO;
            // rows i, i+1 for ALL trailing columns (off-block coupling keeps
            // the full Schur form consistent, not just the active block).
            for j in i + 1..n {
                let (x, y) = (h[(i, j)], h[(i + 1, j)]);
                h[(i, j)] = x.scale(c) + s * y;
                h[(i + 1, j)] = y.scale(c) - s.conj() * x;
            }
            rots.push((c, s));
        }
        // RQᴴ: apply each rotation from the right to columns i, i+1.
        for (t, &(c, s)) in rots.iter().enumerate() {
            let i = lo + t;
            let top = (i + 1).min(hi) + 1; // rows 0..top participate
            for r_ in 0..top.min(n) {
                let (x, y) = (h[(r_, i)], h[(r_, i + 1)]);
                h[(r_, i)] = x.scale(c) + y * s.conj();
                h[(r_, i + 1)] = y.scale(c) - x * s;
            }
            for r_ in 0..n {
                let (x, y) = (z[(r_, i)], z[(r_, i + 1)]);
                z[(r_, i)] = x.scale(c) + y * s.conj();
                z[(r_, i + 1)] = y.scale(c) - x * s;
            }
        }
        for i in lo..=hi {
            h[(i, i)] += mu;
        }
    }
    // Zero strictly-lower storage noise.
    for j in 0..n {
        for i in j + 1..n {
            h[(i, j)] = C64::ZERO;
        }
    }
    Ok((h, z))
}

/// Eigenvectors of an upper-triangular T by back-substitution; column k pairs
/// with T[k,k].
fn triangular_eigvecs(t: &ZMat) -> ZMat {
    let n = t.nrows;
    let mut v = ZMat::zeros(n, n);
    let tnorm = t.fro_norm().max(1e-300);
    for k in 0..n {
        let lam = t[(k, k)];
        v[(k, k)] = C64::ONE;
        for j in (0..k).rev() {
            // y[j] = -(Σ_{i=j+1..=k} T[j,i] y[i]) / (T[j,j] - λ)
            let mut s = C64::ZERO;
            for i in j + 1..=k {
                s += t[(j, i)] * v[(i, k)];
            }
            let mut d = t[(j, j)] - lam;
            if d.abs() < 1e-14 * tnorm {
                // Perturb a (near-)defective denominator; standard LAPACK trick.
                d = C64::real(1e-14 * tnorm);
            }
            v[(j, k)] = -s / d;
        }
        // Normalize.
        let nrm = (0..=k).map(|i| v[(i, k)].norm_sqr()).sum::<f64>().sqrt();
        if nrm > 0.0 {
            for i in 0..=k {
                v[(i, k)] = v[(i, k)].scale(1.0 / nrm);
            }
        }
    }
    v
}

/// Full eigendecomposition of a general complex matrix.
pub fn eig(a: &ZMat) -> Result<Eig> {
    let (t, z) = schur(a)?;
    let n = a.nrows;
    let values: Vec<C64> = (0..n).map(|i| t[(i, i)]).collect();
    let vt = triangular_eigvecs(&t);
    let vectors = z.matmul(&vt);
    Ok(Eig { values, vectors })
}

/// Generalized eigenproblem A z = θ B z for small dense complex matrices,
/// solved as B⁻¹A z = θ z (B must be nonsingular — true for the harmonic-Ritz
/// systems as long as the Arnoldi basis is full rank).
pub fn eig_generalized(a: &ZMat, b: &ZMat) -> Result<Eig> {
    let n = a.nrows;
    assert_eq!(b.nrows, n);
    let binv_a = b.solve_columns(a)?;
    eig(&binv_a)
}

/// Indices of the `k` smallest-|θ| eigenvalues.
pub fn smallest_k_indices(values: &[C64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&i, &j| values[i].norm_sqr().partial_cmp(&values[j].norm_sqr()).unwrap());
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::dense::Mat;
    use crate::util::prng::Rng;

    fn residual(a: &ZMat, e: &Eig) -> f64 {
        let n = a.nrows;
        let mut worst: f64 = 0.0;
        for k in 0..n {
            let mut r = vec![C64::ZERO; n];
            for i in 0..n {
                for j in 0..n {
                    r[i] += a[(i, j)] * e.vectors[(j, k)];
                }
                r[i] -= e.values[k] * e.vectors[(i, k)];
            }
            let nrm = r.iter().map(|z| z.norm_sqr()).sum::<f64>().sqrt();
            worst = worst.max(nrm);
        }
        worst
    }

    #[test]
    fn diagonal_matrix() {
        let mut a = ZMat::zeros(3, 3);
        a[(0, 0)] = C64::real(3.0);
        a[(1, 1)] = C64::real(-1.0);
        a[(2, 2)] = C64::real(0.5);
        let e = eig(&a).unwrap();
        let mut vals: Vec<f64> = e.values.iter().map(|z| z.re).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((vals[0] + 1.0).abs() < 1e-12);
        assert!((vals[1] - 0.5).abs() < 1e-12);
        assert!((vals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn rotation_has_complex_pair() {
        // [[cos, -sin], [sin, cos]] has eigenvalues e^{±iθ}.
        let th = 0.7f64;
        let a = ZMat::from_real(&Mat::from_rows(&[&[th.cos(), -th.sin()], &[th.sin(), th.cos()]]));
        let e = eig(&a).unwrap();
        let mut ims: Vec<f64> = e.values.iter().map(|z| z.im).collect();
        ims.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((ims[0] + th.sin()).abs() < 1e-10);
        assert!((ims[1] - th.sin()).abs() < 1e-10);
        assert!(residual(&a, &e) < 1e-10);
    }

    #[test]
    fn random_matrices_small_residual_and_trace() {
        let mut rng = Rng::new(11);
        for n in [4usize, 9, 16, 33] {
            let mut m = Mat::zeros(n, n);
            for v in &mut m.data {
                *v = rng.normal();
            }
            let a = ZMat::from_real(&m);
            let e = eig(&a).unwrap();
            // Eigenvalue sum == trace.
            let tr: f64 = (0..n).map(|i| m[(i, i)]).sum();
            let s: C64 = e.values.iter().fold(C64::ZERO, |acc, &z| acc + z);
            assert!((s.re - tr).abs() < 1e-8 * (1.0 + tr.abs()), "n={n} trace");
            assert!(s.im.abs() < 1e-8, "n={n} imag trace {}", s.im);
            assert!(residual(&a, &e) < 1e-7, "n={n} residual {}", residual(&a, &e));
        }
    }

    #[test]
    fn hessenberg_preserves_similarity() {
        let mut rng = Rng::new(5);
        let n = 8;
        let mut m = Mat::zeros(n, n);
        for v in &mut m.data {
            *v = rng.normal();
        }
        let a = ZMat::from_real(&m);
        let (h, q) = hessenberg(&a);
        // Q H Qᴴ == A
        let back = q.matmul(&h).matmul(&q.adjoint());
        let mut diff: f64 = 0.0;
        for k in 0..back.data.len() {
            diff = diff.max((back.data[k] - a.data[k]).abs());
        }
        assert!(diff < 1e-10, "{diff}");
        // H is Hessenberg
        for j in 0..n {
            for i in j + 2..n {
                assert!(h[(i, j)].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn generalized_reduces_to_standard_with_identity_b() {
        let a = ZMat::from_real(&Mat::from_rows(&[&[2.0, 1.0], &[0.0, 3.0]]));
        let e = eig_generalized(&a, &ZMat::eye(2)).unwrap();
        let mut vals: Vec<f64> = e.values.iter().map(|z| z.re).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((vals[0] - 2.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn smallest_k_selection() {
        let vals = vec![C64::real(5.0), C64::new(0.0, 0.1), C64::real(-2.0), C64::new(1.0, 1.0)];
        let idx = smallest_k_indices(&vals, 2);
        assert_eq!(idx, vec![1, 3]);
    }
}
