//! Small complex dense matrices (column-major) for the harmonic-Ritz
//! eigenproblems inside GCRO-DR. Sizes are O(m) ≈ 30–80, so clarity wins
//! over blocking.

use super::c64::C64;

/// Column-major complex matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct ZMat {
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<C64>,
}

impl ZMat {
    pub fn zeros(nrows: usize, ncols: usize) -> ZMat {
        ZMat { nrows, ncols, data: vec![C64::ZERO; nrows * ncols] }
    }

    pub fn eye(n: usize) -> ZMat {
        let mut m = ZMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = C64::ONE;
        }
        m
    }

    /// Lift a real matrix.
    pub fn from_real(a: &super::dense::Mat) -> ZMat {
        let mut m = ZMat::zeros(a.nrows, a.ncols);
        for j in 0..a.ncols {
            for i in 0..a.nrows {
                m[(i, j)] = C64::real(a[(i, j)]);
            }
        }
        m
    }

    pub fn matmul(&self, b: &ZMat) -> ZMat {
        assert_eq!(self.ncols, b.nrows);
        let mut c = ZMat::zeros(self.nrows, b.ncols);
        for j in 0..b.ncols {
            for k in 0..self.ncols {
                let bkj = b[(k, j)];
                if bkj == C64::ZERO {
                    continue;
                }
                for i in 0..self.nrows {
                    let v = self[(i, k)] * bkj;
                    c[(i, j)] += v;
                }
            }
        }
        c
    }

    /// Conjugate transpose.
    pub fn adjoint(&self) -> ZMat {
        let mut t = ZMat::zeros(self.ncols, self.nrows);
        for j in 0..self.ncols {
            for i in 0..self.nrows {
                t[(j, i)] = self[(i, j)].conj();
            }
        }
        t
    }

    /// Solve A X = B column-wise with a single LU factorization (O(n³ + n²·k)
    /// rather than O(n³·k) for k right-hand sides).
    pub fn solve_columns(&self, rhs: &ZMat) -> anyhow::Result<ZMat> {
        let n = self.nrows;
        assert_eq!(self.ncols, n);
        assert_eq!(rhs.nrows, n);
        let mut a = self.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        // Factor PA = LU in place.
        for k in 0..n {
            let mut p = k;
            for i in k + 1..n {
                if a[(i, k)].norm_sqr() > a[(p, k)].norm_sqr() {
                    p = i;
                }
            }
            if a[(p, k)].norm_sqr() < 1e-300 {
                anyhow::bail!("singular complex system at column {k}");
            }
            if p != k {
                for j in 0..n {
                    let (u, v) = (a[(k, j)], a[(p, j)]);
                    a[(k, j)] = v;
                    a[(p, j)] = u;
                }
                perm.swap(k, p);
            }
            for i in k + 1..n {
                let l = a[(i, k)] / a[(k, k)];
                a[(i, k)] = l;
                for j in k + 1..n {
                    let akj = a[(k, j)];
                    a[(i, j)] -= l * akj;
                }
            }
        }
        let mut out = ZMat::zeros(n, rhs.ncols);
        for c in 0..rhs.ncols {
            // Permuted rhs.
            let mut x: Vec<C64> = (0..n).map(|i| rhs[(perm[i], c)]).collect();
            for i in 0..n {
                for j in 0..i {
                    let lij = a[(i, j)];
                    let xj = x[j];
                    x[i] -= lij * xj;
                }
            }
            for i in (0..n).rev() {
                for j in i + 1..n {
                    let uij = a[(i, j)];
                    let xj = x[j];
                    x[i] -= uij * xj;
                }
                x[i] = x[i] / a[(i, i)];
            }
            for i in 0..n {
                out[(i, c)] = x[i];
            }
        }
        Ok(out)
    }

    /// Solve A x = b by complex partial-pivot LU (small systems).
    pub fn solve(&self, b: &[C64]) -> anyhow::Result<Vec<C64>> {
        let n = self.nrows;
        assert_eq!(self.ncols, n);
        assert_eq!(b.len(), n);
        let mut a = self.clone();
        let mut x = b.to_vec();
        for k in 0..n {
            let mut p = k;
            for i in k + 1..n {
                if a[(i, k)].norm_sqr() > a[(p, k)].norm_sqr() {
                    p = i;
                }
            }
            if a[(p, k)].norm_sqr() < 1e-300 {
                anyhow::bail!("singular complex system at column {k}");
            }
            if p != k {
                for j in 0..n {
                    let (u, v) = (a[(k, j)], a[(p, j)]);
                    a[(k, j)] = v;
                    a[(p, j)] = u;
                }
                x.swap(k, p);
            }
            for i in k + 1..n {
                let l = a[(i, k)] / a[(k, k)];
                a[(i, k)] = l;
                for j in k + 1..n {
                    let akj = a[(k, j)];
                    a[(i, j)] -= l * akj;
                }
                let xk = x[k];
                x[i] -= l * xk;
            }
        }
        for i in (0..n).rev() {
            for j in i + 1..n {
                let xj = x[j];
                x[i] -= a[(i, j)] * xj;
            }
            x[i] = x[i] / a[(i, i)];
        }
        Ok(x)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|z| z.norm_sqr()).sum::<f64>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for ZMat {
    type Output = C64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &C64 {
        &self.data[j * self.nrows + i]
    }
}

impl std::ops::IndexMut<(usize, usize)> for ZMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut C64 {
        &mut self.data[j * self.nrows + i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_and_adjoint() {
        let mut a = ZMat::zeros(2, 2);
        a[(0, 0)] = C64::new(1.0, 1.0);
        a[(0, 1)] = C64::new(0.0, 2.0);
        a[(1, 0)] = C64::new(3.0, 0.0);
        a[(1, 1)] = C64::new(1.0, -1.0);
        let aa = a.adjoint();
        assert_eq!(aa[(0, 0)], C64::new(1.0, -1.0));
        assert_eq!(aa[(1, 0)], C64::new(0.0, -2.0));
        let prod = a.matmul(&ZMat::eye(2));
        assert_eq!(prod, a);
    }

    #[test]
    fn solve_roundtrip() {
        let mut a = ZMat::zeros(3, 3);
        let vals = [
            (0, 0, 2.0, 1.0),
            (0, 1, 1.0, 0.0),
            (0, 2, 0.0, -1.0),
            (1, 0, 0.0, 1.0),
            (1, 1, 3.0, 0.0),
            (1, 2, 1.0, 1.0),
            (2, 0, 1.0, 0.0),
            (2, 1, 0.0, 0.0),
            (2, 2, 4.0, -2.0),
        ];
        for (i, j, re, im) in vals {
            a[(i, j)] = C64::new(re, im);
        }
        let xt = vec![C64::new(1.0, -1.0), C64::new(2.0, 0.5), C64::new(-0.5, 2.0)];
        // b = A x
        let mut b = vec![C64::ZERO; 3];
        for i in 0..3 {
            for j in 0..3 {
                b[i] += a[(i, j)] * xt[j];
            }
        }
        let x = a.solve(&b).unwrap();
        for (u, v) in x.iter().zip(&xt) {
            assert!((*u - *v).abs() < 1e-12);
        }
    }
}
