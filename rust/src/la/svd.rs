//! One-sided Jacobi SVD for small dense matrices.
//!
//! Used by the δ-subspace instrument: for orthonormal bases C and Q the
//! one-sided distance δ(Q, C) = ‖(I − Π_C) Π_Q‖₂ equals sin of the largest
//! principal angle, computable from the singular values of CᵀQ.

use super::dense::Mat;

/// Singular values of `a` (descending), via one-sided Jacobi on the columns.
pub fn singular_values(a: &Mat) -> Vec<f64> {
    let (u_s, _v) = jacobi_svd(a);
    let mut s: Vec<f64> = (0..u_s.ncols).map(|j| crate::la::norm2(u_s.col(j))).collect();
    s.sort_by(|x, y| y.partial_cmp(x).unwrap());
    s
}

/// One-sided Jacobi: returns (U·Σ, V) with a = (U·Σ) Vᵀ; columns of the first
/// factor are mutually orthogonal with norms = singular values.
pub fn jacobi_svd(a: &Mat) -> (Mat, Mat) {
    let mut u = a.clone();
    let n = u.ncols;
    let mut v = Mat::eye(n);
    let tol = 1e-14;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                let (cp, cq): (Vec<f64>, Vec<f64>) = (u.col(p).to_vec(), u.col(q).to_vec());
                let app = crate::la::dot(&cp, &cp);
                let aqq = crate::la::dot(&cq, &cq);
                let apq = crate::la::dot(&cp, &cq);
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation that orthogonalizes columns p and q.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..u.nrows {
                    let (x, y) = (u[(i, p)], u[(i, q)]);
                    u[(i, p)] = c * x - s * y;
                    u[(i, q)] = s * x + c * y;
                }
                for i in 0..n {
                    let (x, y) = (v[(i, p)], v[(i, q)]);
                    v[(i, p)] = c * x - s * y;
                    v[(i, q)] = s * x + c * y;
                }
            }
        }
        if off < tol {
            break;
        }
    }
    (u, v)
}

/// Largest principal-angle sine between the column spaces of two matrices
/// with **orthonormal** columns: δ = √(1 − σ_min(CᵀQ)²), clamped to [0, 1].
pub fn subspace_sin_max(c: &Mat, q: &Mat) -> f64 {
    assert_eq!(c.nrows, q.nrows);
    let m = c.transpose().matmul(q);
    let s = singular_values(&m);
    let smin = s.last().copied().unwrap_or(0.0).clamp(0.0, 1.0);
    (1.0 - smin * smin).max(0.0).sqrt()
}

/// Mean principal-angle sine between two orthonormal column spaces. The
/// spectral δ saturates at 1 as soon as a *single* direction is badly
/// matched (typical for k ≳ 5 subspaces of a large ambient space), so the
/// mean over all k angles is the discriminative variant reported by the
/// sort ablation.
pub fn subspace_sin_mean(c: &Mat, q: &Mat) -> f64 {
    assert_eq!(c.nrows, q.nrows);
    let m = c.transpose().matmul(q);
    let s = singular_values(&m);
    if s.is_empty() {
        return 1.0;
    }
    s.iter()
        .map(|&sv| {
            let sv = sv.clamp(0.0, 1.0);
            (1.0 - sv * sv).max(0.0).sqrt()
        })
        .sum::<f64>()
        / s.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn singular_values_of_diagonal() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, -4.0], &[0.0, 0.0]]);
        let s = singular_values(&a);
        assert!((s[0] - 4.0).abs() < 1e-12);
        assert!((s[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn svd_frobenius_invariant() {
        let mut rng = Rng::new(8);
        let mut a = Mat::zeros(7, 5);
        for v in &mut a.data {
            *v = rng.normal();
        }
        let s = singular_values(&a);
        let f2: f64 = s.iter().map(|x| x * x).sum();
        assert!((f2 - a.fro_norm().powi(2)).abs() < 1e-9);
    }

    #[test]
    fn identical_subspaces_have_zero_distance() {
        let mut rng = Rng::new(9);
        let mut a = Mat::zeros(10, 3);
        for v in &mut a.data {
            *v = rng.normal();
        }
        let (q, _) = a.qr_thin();
        let d = subspace_sin_max(&q, &q);
        assert!(d < 1e-7, "{d}");
    }

    #[test]
    fn orthogonal_subspaces_have_distance_one() {
        // e1,e2 vs e3,e4 in R^4.
        let mut c = Mat::zeros(4, 2);
        c[(0, 0)] = 1.0;
        c[(1, 1)] = 1.0;
        let mut q = Mat::zeros(4, 2);
        q[(2, 0)] = 1.0;
        q[(3, 1)] = 1.0;
        assert!((subspace_sin_max(&c, &q) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rotated_subspace_angle() {
        // span{e1} vs span{cosθ e1 + sinθ e2} → δ = sinθ.
        let th = 0.3f64;
        let mut c = Mat::zeros(3, 1);
        c[(0, 0)] = 1.0;
        let mut q = Mat::zeros(3, 1);
        q[(0, 0)] = th.cos();
        q[(1, 0)] = th.sin();
        assert!((subspace_sin_max(&c, &q) - th.sin()).abs() < 1e-12);
    }
}
