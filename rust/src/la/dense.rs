//! Dense real matrices (column-major) with the factorizations the Krylov
//! machinery needs: Householder QR (thin), triangular solves, small-system
//! LU solve, and general least squares.

use anyhow::{bail, Result};

/// Column-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub nrows: usize,
    pub ncols: usize,
    /// Column-major storage: element (i, j) at `data[j * nrows + i]`.
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(nrows: usize, ncols: usize) -> Mat {
        Mat { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major nested slices (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let nrows = rows.len();
        let ncols = if nrows > 0 { rows[0].len() } else { 0 };
        let mut m = Mat::zeros(nrows, ncols);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), ncols);
            for (j, &v) in r.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        m
    }

    /// Column `j` as a slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// Column `j` as a mutable slice.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        let n = self.nrows;
        &mut self.data[j * n..(j + 1) * n]
    }

    /// Set column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.nrows);
        self.col_mut(j).copy_from_slice(v);
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.ncols, self.nrows);
        for j in 0..self.ncols {
            for i in 0..self.nrows {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// C = A * B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.ncols, b.nrows);
        let mut c = Mat::zeros(self.nrows, b.ncols);
        for j in 0..b.ncols {
            for k in 0..self.ncols {
                let bkj = b[(k, j)];
                if bkj == 0.0 {
                    continue;
                }
                let a_col = self.col(k);
                let c_col = c.col_mut(j);
                for i in 0..a_col.len() {
                    c_col[i] += a_col[i] * bkj;
                }
            }
        }
        c
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for j in 0..self.ncols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            let col = self.col(j);
            for i in 0..self.nrows {
                y[i] += col[i] * xj;
            }
        }
        y
    }

    /// y = Aᵀ x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.nrows);
        (0..self.ncols).map(|j| crate::la::dot(self.col(j), x)).collect()
    }

    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Thin Householder QR: self (m×n, m ≥ n) = Q (m×n, orthonormal cols) R (n×n upper).
    pub fn qr_thin(&self) -> (Mat, Mat) {
        let (m, n) = (self.nrows, self.ncols);
        assert!(m >= n, "qr_thin requires m >= n");
        let mut a = self.clone();
        // Householder vectors stored in-place below the diagonal; betas aside.
        let mut betas = vec![0.0; n];
        for k in 0..n {
            // Build v for column k.
            let mut normx = 0.0;
            for i in k..m {
                normx += a[(i, k)] * a[(i, k)];
            }
            normx = normx.sqrt();
            if normx == 0.0 {
                betas[k] = 0.0;
                continue;
            }
            let alpha = if a[(k, k)] >= 0.0 { -normx } else { normx };
            let v0 = a[(k, k)] - alpha;
            a[(k, k)] = alpha;
            let mut vtv = v0 * v0;
            for i in k + 1..m {
                vtv += a[(i, k)] * a[(i, k)];
            }
            betas[k] = if vtv == 0.0 { 0.0 } else { 2.0 / vtv };
            // Apply H to trailing columns. v = [v0, a[k+1.., k]].
            for j in k + 1..n {
                let mut s = v0 * a[(k, j)];
                for i in k + 1..m {
                    s += a[(i, k)] * a[(i, j)];
                }
                s *= betas[k];
                a[(k, j)] -= s * v0;
                for i in k + 1..m {
                    let aik = a[(i, k)];
                    a[(i, j)] -= s * aik;
                }
            }
            // Store normalized v tail in-place (below diag of column k), with
            // implicit v0 stored separately — reuse betas structure by storing
            // v0 in a shadow: we scale the tail by 1/v0 so v0 == 1 implicitly.
            if v0 != 0.0 {
                for i in k + 1..m {
                    a[(i, k)] /= v0;
                }
                betas[k] *= v0 * v0;
            }
        }
        // Extract R.
        let mut r = Mat::zeros(n, n);
        for j in 0..n {
            for i in 0..=j {
                r[(i, j)] = a[(i, j)];
            }
        }
        // Form thin Q by applying H_0 .. H_{n-1} to the first n columns of I.
        let mut q = Mat::zeros(m, n);
        for j in 0..n {
            q[(j, j)] = 1.0;
        }
        for k in (0..n).rev() {
            let beta = betas[k];
            if beta == 0.0 {
                continue;
            }
            for j in 0..n {
                // v = [1, a[k+1.., k]]
                let mut s = q[(k, j)];
                for i in k + 1..m {
                    s += a[(i, k)] * q[(i, j)];
                }
                s *= beta;
                q[(k, j)] -= s;
                for i in k + 1..m {
                    let aik = a[(i, k)];
                    q[(i, j)] -= s * aik;
                }
            }
        }
        (q, r)
    }

    /// Solve R x = b with R upper triangular.
    pub fn solve_upper(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.ncols;
        assert_eq!(self.nrows, n);
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            for j in i + 1..n {
                x[i] -= self[(i, j)] * x[j];
            }
            let d = self[(i, i)];
            if d.abs() < 1e-300 {
                bail!("singular upper-triangular system at row {i}");
            }
            x[i] /= d;
        }
        Ok(x)
    }

    /// Least-squares solve min ||A x - b|| via thin QR (m ≥ n).
    pub fn lstsq(&self, b: &[f64]) -> Result<Vec<f64>> {
        let (q, r) = self.qr_thin();
        let qtb = q.matvec_t(b);
        r.solve_upper(&qtb)
    }

    /// Solve A x = b with partial-pivot LU (small square systems).
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.nrows;
        assert_eq!(self.ncols, n);
        assert_eq!(b.len(), n);
        let mut a = self.clone();
        let mut x = b.to_vec();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // pivot
            let mut p = k;
            for i in k + 1..n {
                if a[(i, k)].abs() > a[(p, k)].abs() {
                    p = i;
                }
            }
            if a[(p, k)].abs() < 1e-300 {
                bail!("singular matrix in LU at column {k}");
            }
            if p != k {
                for j in 0..n {
                    let (u, v) = (a[(k, j)], a[(p, j)]);
                    a[(k, j)] = v;
                    a[(p, j)] = u;
                }
                x.swap(k, p);
                piv.swap(k, p);
            }
            for i in k + 1..n {
                let l = a[(i, k)] / a[(k, k)];
                a[(i, k)] = l;
                for j in k + 1..n {
                    let akj = a[(k, j)];
                    a[(i, j)] -= l * akj;
                }
                x[i] -= l * x[k];
            }
        }
        // back substitution
        for i in (0..n).rev() {
            for j in i + 1..n {
                x[i] -= a[(i, j)] * x[j];
            }
            x[i] /= a[(i, i)];
        }
        Ok(x)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[j * self.nrows + i]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[j * self.nrows + i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random(m: usize, n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::zeros(m, n);
        for v in &mut a.data {
            *v = rng.normal();
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(1);
        let a = random(4, 3, &mut r);
        let i3 = Mat::eye(3);
        assert!((a.matmul(&i3).data.iter().zip(&a.data).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)) < 1e-15);
    }

    #[test]
    fn qr_reconstructs_and_orthonormal() {
        let mut rng = Rng::new(2);
        for &(m, n) in &[(5, 3), (8, 8), (10, 2)] {
            let a = random(m, n, &mut rng);
            let (q, r) = a.qr_thin();
            let qr = q.matmul(&r);
            for k in 0..a.data.len() {
                assert!((qr.data[k] - a.data[k]).abs() < 1e-10, "m={m} n={n}");
            }
            let qtq = q.transpose().matmul(&q);
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((qtq[(i, j)] - want).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn lstsq_exact_for_square() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = a.lstsq(&[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lstsq_overdetermined_residual_orthogonal() {
        let mut rng = Rng::new(3);
        let a = random(10, 4, &mut rng);
        let b: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let x = a.lstsq(&b).unwrap();
        let ax = a.matvec(&x);
        let res: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        // Aᵀ r == 0 at the LS optimum.
        let atr = a.matvec_t(&res);
        assert!(atr.iter().all(|v| v.abs() < 1e-9), "{atr:?}");
    }

    #[test]
    fn lu_solve_roundtrip() {
        let mut rng = Rng::new(4);
        let a = random(6, 6, &mut rng);
        let xtrue: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let b = a.matvec(&xtrue);
        let x = a.solve(&b).unwrap();
        for (u, v) in x.iter().zip(&xtrue) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_upper_detects_singular() {
        let r = Mat::from_rows(&[&[1.0, 2.0], &[0.0, 0.0]]);
        assert!(r.solve_upper(&[1.0, 1.0]).is_err());
    }
}
