//! Orthogonalization kernels: modified Gram–Schmidt with DGKS
//! reorthogonalization — the inner loop of both Arnoldi processes.

use super::{axpy, dot, norm2};

/// Orthogonalize `w` in place against the orthonormal columns in `basis`
/// (each of length `w.len()`), returning the projection coefficients.
/// Performs one MGS pass plus a DGKS reorthogonalization pass when the norm
/// drops sharply (classic 1/√2 criterion) — this is what keeps long GMRES
/// cycles numerically orthogonal.
pub fn mgs_orthogonalize(w: &mut [f64], basis: &[Vec<f64>]) -> Vec<f64> {
    let mut coeffs = vec![0.0; basis.len()];
    let before = norm2(w);
    for (j, v) in basis.iter().enumerate() {
        let h = dot(v, w);
        coeffs[j] = h;
        axpy(-h, v, w);
    }
    let after = norm2(w);
    if after < before / std::f64::consts::SQRT_2 {
        for (j, v) in basis.iter().enumerate() {
            let h = dot(v, w);
            coeffs[j] += h;
            axpy(-h, v, w);
        }
    }
    coeffs
}

/// Four dot products against `w` in a single pass over memory. The Arnoldi
/// orthogonalization is memory-bound (each `dot` streams both vectors from
/// DRAM); batching four basis vectors per pass cuts the traffic on `w` 4×.
#[inline]
fn dot4(v0: &[f64], v1: &[f64], v2: &[f64], v3: &[f64], w: &[f64]) -> [f64; 4] {
    // Pre-bound every slice to the common length so the indexed loop carries
    // no per-element bounds checks and auto-vectorises.
    let n = w.len();
    let (v0, v1, v2, v3) = (&v0[..n], &v1[..n], &v2[..n], &v3[..n]);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..n {
        let wi = w[i];
        s0 += v0[i] * wi;
        s1 += v1[i] * wi;
        s2 += v2[i] * wi;
        s3 += v3[i] * wi;
    }
    [s0, s1, s2, s3]
}

/// w −= Σ hⱼ vⱼ over four columns in a single pass.
#[inline]
fn axpy4(h: [f64; 4], v0: &[f64], v1: &[f64], v2: &[f64], v3: &[f64], w: &mut [f64]) {
    let n = w.len();
    let (v0, v1, v2, v3) = (&v0[..n], &v1[..n], &v2[..n], &v3[..n]);
    for i in 0..n {
        w[i] -= h[0] * v0[i] + h[1] * v1[i] + h[2] * v2[i] + h[3] * v3[i];
    }
}

/// One classical-Gram–Schmidt projection sweep with 4-way blocked passes:
/// `coeffs += Vᵀw; w −= V (Vᵀw)`. Returns nothing; `coeffs` accumulates.
fn cgs_sweep(w: &mut [f64], basis: &[Vec<f64>], coeffs: &mut [f64]) {
    let nb = basis.len();
    let blocks = nb / 4;
    // Batched projection coefficients (all dots against the *same* w — this
    // is the classical, not modified, variant; the second sweep restores
    // MGS-grade orthogonality per Giraud et al.).
    let mut h = vec![0.0; nb];
    for b in 0..blocks {
        let j = 4 * b;
        let hb = dot4(&basis[j], &basis[j + 1], &basis[j + 2], &basis[j + 3], w);
        h[j..j + 4].copy_from_slice(&hb);
    }
    for j in 4 * blocks..nb {
        h[j] = dot(&basis[j], w);
    }
    for b in 0..blocks {
        let j = 4 * b;
        axpy4(
            [h[j], h[j + 1], h[j + 2], h[j + 3]],
            &basis[j],
            &basis[j + 1],
            &basis[j + 2],
            &basis[j + 3],
            w,
        );
    }
    for j in 4 * blocks..nb {
        axpy(-h[j], &basis[j], w);
    }
    for (c, hj) in coeffs.iter_mut().zip(&h) {
        *c += hj;
    }
}

/// Orthogonalize `w` against `basis` with CGS2 (two blocked classical
/// Gram–Schmidt sweeps — "twice is enough"): numerically as orthogonal as
/// MGS + DGKS, but every sweep streams `w` once per 4 basis vectors instead
/// of twice per vector, which is ~2–3× faster for long Arnoldi cycles.
/// Returns the accumulated projection coefficients.
pub fn cgs2_orthogonalize(w: &mut [f64], basis: &[Vec<f64>]) -> Vec<f64> {
    let mut coeffs = vec![0.0; basis.len()];
    if basis.is_empty() {
        return coeffs;
    }
    let before = norm2(w);
    cgs_sweep(w, basis, &mut coeffs);
    // DGKS criterion: the classical sweep loses orthogonality only when it
    // cancels most of w; re-sweep then (and only then).
    if norm2(w) < before / std::f64::consts::SQRT_2 {
        cgs_sweep(w, basis, &mut coeffs);
    }
    coeffs
}

/// Normalize `w` in place; returns the norm (0.0 signals breakdown).
pub fn normalize(w: &mut [f64]) -> f64 {
    let n = norm2(w);
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in w.iter_mut() {
            *x *= inv;
        }
    }
    n
}

/// Max |⟨vᵢ, vⱼ⟩ − δᵢⱼ| over a basis — orthonormality defect, used in tests
/// and the solver's debug assertions.
pub fn orthonormality_defect(basis: &[Vec<f64>]) -> f64 {
    let mut worst: f64 = 0.0;
    for i in 0..basis.len() {
        for j in i..basis.len() {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((dot(&basis[i], &basis[j]) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn orthogonalizes_random_vectors() {
        let mut rng = Rng::new(13);
        let n = 50;
        let mut basis: Vec<Vec<f64>> = Vec::new();
        for _ in 0..10 {
            let mut w = rng.normals(n);
            mgs_orthogonalize(&mut w, &basis);
            let nrm = normalize(&mut w);
            assert!(nrm > 0.0);
            basis.push(w);
        }
        assert!(orthonormality_defect(&basis) < 1e-12);
    }

    #[test]
    fn reorthogonalization_handles_near_dependence() {
        let mut rng = Rng::new(14);
        let n = 40;
        let v0 = {
            let mut v = rng.normals(n);
            normalize(&mut v);
            v
        };
        // w is v0 plus a tiny perturbation: after MGS it must still be
        // orthogonal to v0 to machine precision.
        let mut w = v0.clone();
        for x in w.iter_mut() {
            *x += 1e-10 * rng.normal();
        }
        let basis = vec![v0.clone()];
        mgs_orthogonalize(&mut w, &basis);
        if normalize(&mut w) > 0.0 {
            assert!(dot(&w, &v0).abs() < 1e-10);
        }
    }

    #[test]
    fn coefficients_reconstruct_projection() {
        let mut rng = Rng::new(15);
        let n = 30;
        let mut basis: Vec<Vec<f64>> = Vec::new();
        for _ in 0..5 {
            let mut w = rng.normals(n);
            mgs_orthogonalize(&mut w, &basis);
            normalize(&mut w);
            basis.push(w);
        }
        let orig = rng.normals(n);
        let mut w = orig.clone();
        let coeffs = mgs_orthogonalize(&mut w, &basis);
        // orig == Σ coeffs_j v_j + w
        let mut recon = w.clone();
        for (c, v) in coeffs.iter().zip(&basis) {
            axpy(*c, v, &mut recon);
        }
        for (a, b) in recon.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
