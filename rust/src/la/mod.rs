//! Linear-algebra substrate: CSR sparse matrices with shared structure
//! ([`Sparsity`] behind an `Arc` + per-system values), dense (real and
//! complex) matrices, Householder QR, a complex Hessenberg-QR eigensolver, a
//! one-sided Jacobi SVD, and orthogonalization kernels. Everything the Krylov
//! solvers and the δ-subspace instrumentation need, implemented in-tree.

pub mod c64;
pub mod csr;
pub mod dense;
pub mod eig;
pub mod ortho;
pub mod sparsity;
pub mod svd;
pub mod zmat;

pub use c64::C64;
pub use csr::Csr;
pub use dense::Mat;
pub use sparsity::Sparsity;
pub use zmat::ZMat;

/// Euclidean norm of a slice.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Dot product. The hot path of every Krylov iteration; kept as a plain
/// indexed loop which LLVM auto-vectorises (verified in the perf pass).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for i in 0..chunks {
        let j = 4 * i;
        s0 += x[j] * y[j];
        s1 += x[j + 1] * y[j + 1];
        s2 += x[j + 2] * y[j + 2];
        s3 += x[j + 3] * y[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in 4 * chunks..n {
        s += x[j] * y[j];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// x *= alpha
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blas1() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut y = vec![1.0; 5];
        assert!((dot(&x, &y) - 15.0).abs() < 1e-14);
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![1.5, 2.5, 3.5, 4.5, 5.5]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-14);
    }
}
