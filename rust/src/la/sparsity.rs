//! Shared CSR structure — the pattern half of the matrix model.
//!
//! Every system in a generation run is discretised on the same grid with the
//! same stencil, so the `row_ptr`/`col_idx` structure is identical across the
//! whole sequence; only the numeric values differ. [`Sparsity`] captures that
//! structure once, is shared between systems behind an `Arc`, and carries the
//! precomputed diagonal positions that the symbolic preconditioner phases
//! (ILU0/ICC0/ASM/BlockJacobi) key on.

use anyhow::{bail, Result};

/// Immutable CSR structure: dimensions, row offsets, sorted column indices,
/// and precomputed main-diagonal positions.
#[derive(Debug, Clone, PartialEq)]
pub struct Sparsity {
    nrows: usize,
    ncols: usize,
    /// Row start offsets, length `nrows + 1`.
    pub row_ptr: Vec<usize>,
    /// Column indices, sorted strictly increasing within each row.
    pub col_idx: Vec<usize>,
    /// Position of entry (i, i) in `col_idx`/values order, `usize::MAX`
    /// where the diagonal is structurally absent.
    diag_pos: Vec<usize>,
}

impl Sparsity {
    /// Build from (row, col) pairs; duplicates collapse to one entry.
    pub fn from_pattern(nrows: usize, ncols: usize, pattern: &[(usize, usize)]) -> Sparsity {
        let mut entries: Vec<(usize, usize)> = pattern.to_vec();
        entries.sort_unstable();
        entries.dedup();
        let mut row_ptr = vec![0usize; nrows + 1];
        for &(r, c) in &entries {
            assert!(r < nrows && c < ncols, "pattern entry ({r},{c}) out of bounds");
            row_ptr[r + 1] += 1;
        }
        for i in 0..nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let col_idx: Vec<usize> = entries.iter().map(|&(_, c)| c).collect();
        Sparsity::from_parts(nrows, ncols, row_ptr, col_idx)
    }

    /// Assemble from already-built CSR structure arrays (caller guarantees
    /// sorted, in-range columns; `validate` checks in tests).
    pub(crate) fn from_parts(
        nrows: usize,
        ncols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
    ) -> Sparsity {
        let mut s = Sparsity { nrows, ncols, row_ptr, col_idx, diag_pos: Vec::new() };
        s.diag_pos = (0..nrows)
            .map(|i| {
                let (a, b) = (s.row_ptr[i], s.row_ptr[i + 1]);
                match s.col_idx[a..b].binary_search(&i) {
                    Ok(k) => a + k,
                    Err(_) => usize::MAX,
                }
            })
            .collect();
        s
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Value-array range of row `i`.
    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.row_ptr[i]..self.row_ptr[i + 1]
    }

    /// Column indices of row `i`.
    #[inline]
    pub fn row_cols(&self, i: usize) -> &[usize] {
        &self.col_idx[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// Value-array position of the diagonal entry (i, i), if stored.
    #[inline]
    pub fn diag_pos(&self, i: usize) -> Option<usize> {
        let p = self.diag_pos[i];
        if p == usize::MAX {
            None
        } else {
            Some(p)
        }
    }

    /// Value-array position of entry (i, j), if stored (binary search).
    #[inline]
    pub fn pos(&self, i: usize, j: usize) -> Option<usize> {
        let a = self.row_ptr[i];
        self.col_idx[a..self.row_ptr[i + 1]].binary_search(&j).ok().map(|k| a + k)
    }

    /// Validate structural invariants (used by property tests).
    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.nrows + 1 {
            bail!("row_ptr length");
        }
        if *self.row_ptr.last().unwrap() != self.col_idx.len() {
            bail!("ptr/idx mismatch");
        }
        if self.diag_pos.len() != self.nrows {
            bail!("diag_pos length");
        }
        for i in 0..self.nrows {
            if self.row_ptr[i] > self.row_ptr[i + 1] {
                bail!("row_ptr not monotone at {i}");
            }
            let cols = self.row_cols(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    bail!("row {i} columns not strictly increasing");
                }
            }
            if let Some(&c) = cols.last() {
                if c >= self.ncols {
                    bail!("column out of range in row {i}");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_sorts_and_dedups() {
        let s = Sparsity::from_pattern(3, 3, &[(2, 2), (0, 0), (0, 1), (0, 1), (1, 1)]);
        assert_eq!(s.nnz(), 4);
        assert_eq!(s.row_cols(0), &[0, 1]);
        s.validate().unwrap();
    }

    #[test]
    fn positions_resolve() {
        let s = Sparsity::from_pattern(3, 3, &[(0, 0), (0, 2), (1, 0), (2, 1)]);
        assert_eq!(s.pos(0, 2), Some(1));
        assert_eq!(s.pos(1, 0), Some(2));
        assert_eq!(s.pos(1, 1), None);
        assert_eq!(s.diag_pos(0), Some(0));
        assert_eq!(s.diag_pos(1), None);
        assert_eq!(s.diag_pos(2), None);
    }
}
