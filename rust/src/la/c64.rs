//! Complex double-precision scalar (`num-complex` is not in the offline
//! registry; this is the minimal arithmetic the eigensolver needs).

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// Complex f64.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }

    #[inline]
    pub fn real(re: f64) -> C64 {
        C64 { re, im: 0.0 }
    }

    #[inline]
    pub fn conj(self) -> C64 {
        C64 { re: self.re, im: -self.im }
    }

    /// |z|² — cheap magnitude for comparisons.
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    /// Principal square root.
    pub fn sqrt(self) -> C64 {
        let r = self.abs();
        if r == 0.0 {
            return C64::ZERO;
        }
        let re = ((r + self.re) * 0.5).sqrt();
        let im_mag = ((r - self.re) * 0.5).sqrt();
        C64::new(re, if self.im >= 0.0 { im_mag } else { -im_mag })
    }

    #[inline]
    pub fn scale(self, a: f64) -> C64 {
        C64::new(self.re * a, self.im * a)
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline]
    fn div(self, o: C64) -> C64 {
        // Smith's algorithm for robustness against overflow.
        if o.re.abs() >= o.im.abs() {
            if o.re == 0.0 && o.im == 0.0 {
                return C64::new(f64::NAN, f64::NAN);
            }
            let r = o.im / o.re;
            let d = o.re + o.im * r;
            C64::new((self.re + self.im * r) / d, (self.im - self.re * r) / d)
        } else {
            let r = o.re / o.im;
            let d = o.re * r + o.im;
            C64::new((self.re * r + self.im) / d, (self.im * r - self.re) / d)
        }
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline]
    fn neg(self) -> C64 {
        C64::new(-self.re, -self.im)
    }
}

impl AddAssign for C64 {
    #[inline]
    fn add_assign(&mut self, o: C64) {
        *self = *self + o;
    }
}

impl SubAssign for C64 {
    #[inline]
    fn sub_assign(&mut self, o: C64) {
        *self = *self - o;
    }
}

impl MulAssign for C64 {
    #[inline]
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_ops() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        assert_eq!(a + b, C64::new(4.0, 1.0));
        assert_eq!(a * b, C64::new(5.0, 5.0));
        let q = a / b;
        let back = q * b;
        assert!((back - a).abs() < 1e-14);
    }

    #[test]
    fn sqrt_squares_back() {
        for z in [C64::new(2.0, 3.0), C64::new(-4.0, 0.0), C64::new(0.0, -5.0), C64::new(-1.0, -1.0)] {
            let s = z.sqrt();
            assert!((s * s - z).abs() < 1e-12, "{z:?}");
        }
    }

    #[test]
    fn div_by_zero_is_nan() {
        let q = C64::ONE / C64::ZERO;
        assert!(q.re.is_nan());
    }
}
