//! # SKR — Sorting + Krylov Subspace Recycling for Neural-Operator Data Generation
//!
//! A production-quality reproduction of *"Accelerating Data Generation for Neural
//! Operators via Krylov Subspace Recycling"* (ICLR 2024).
//!
//! The library is organised in three layers:
//!
//! * **L3 (this crate)** — the data-generation pipeline: PDE problem families are
//!   sampled, discretised into sparse linear systems, **sorted** by parameter
//!   similarity ([`coordinator::sorter`]), sharded over a worker pool
//!   ([`coordinator::scheduler`]) and solved sequentially with **GCRO-DR Krylov
//!   recycling** ([`solver::gcrodr`]) against a restarted **GMRES** baseline
//!   ([`solver::gmres`]). Every substrate (CSR algebra, dense eigensolvers,
//!   preconditioners, FDM/FVM/FEM discretisations, GRF samplers) is implemented
//!   in-tree.
//! * **L2 (build-time python)** — an FNO-2d forward/backward pass, AOT-lowered to
//!   HLO text (`make artifacts`), loaded from Rust via [`runtime`].
//! * **L1 (build-time python)** — the FNO spectral-convolution Pallas kernel.
//!
//! ## The L3 crate, module by module
//!
//! The in-tree modules mirror how a matrix flows through the system — and how
//! much of it is *shared* along the way (see the README's "Memory model"):
//!
//! * [`la`] — sparse/dense linear algebra. A [`la::Csr`] matrix is a pair of
//!   an immutable, `Arc`-shared [`la::Sparsity`] (structure: `row_ptr`,
//!   `col_idx`, precomputed diagonal positions) and an owned value vector.
//!   Sequences of same-structure systems share one `Sparsity` allocation.
//! * [`pde`] — the four paper problem families (Darcy / Thermal / Poisson /
//!   Helmholtz). Each family builds its pattern (or its whole constant
//!   operator) once per `(family, grid)` and stamps per-sample values onto it.
//! * [`precond`] — the seven preconditioners, each split into a symbolic
//!   phase keyed on the `Sparsity` ([`precond::PrecondKind::symbolic`]: ILU0/
//!   ICC0 fill positions, ASM subdomain maps, block layouts) and a cheap
//!   per-matrix numeric [`precond::SymbolicPrecond::refactor`].
//! * [`solver`] — GMRES(m) / GCRO-DR, plus the reusable [`solver::Workspace`]
//!   (Krylov basis, Hessenberg, Givens, scratch) that sequence drivers thread
//!   through consecutive solves.
//! * [`coordinator`] — sort → shard → solve pipeline; each worker owns one
//!   `Workspace` + cached symbolic preconditioner + recycler per shard.
//! * [`obs`] — spans, JSONL traces, histograms and the structure/symbolic/
//!   workspace reuse counters surfaced by `skr report`.
//! * [`service`] — the `skr serve` daemon: HTTP/JSON job queue over the
//!   pipeline with cancellation, crash-safe journaling and live `/metrics`.
//! * [`dist`] — `skr coordinate` / `skr work`: distributed shard generation
//!   over the same HTTP framing, with lease/heartbeat fault tolerance and a
//!   checksum-verified merge that is byte-identical to a single-node run.
//! * [`bench`] — `skr bench`: named workload manifests, median/IQR timing,
//!   deterministic op counters and the BENCH_*.json regression gate CI runs.
//! * [`harness`], [`no`], [`runtime`] — paper tables/figures, the FNO, PJRT.
//!
//! The public entry points a downstream user needs:
//!
//! * [`coordinator::pipeline::Pipeline`] — end-to-end dataset generation,
//! * [`solver::solve_sequence`] — solve a sequence of systems with either
//!   engine ([`solver::solve_sequence_traced`] also reports reuse tallies),
//! * [`pde`] — the four paper problem families (Darcy / Thermal / Poisson / Helmholtz),
//! * [`no::trainer`] — train the FNO on a generated dataset through the PJRT runtime.

// Configs are deliberately built as `let mut cfg = ..default(); cfg.x = ..`
// field-by-field (mirrors how the CLI layers flags onto defaults).
#![allow(clippy::field_reassign_with_default)]

pub mod bench;
pub mod coordinator;
pub mod dist;
pub mod harness;
pub mod la;
pub mod no;
pub mod obs;
pub mod pde;
pub mod precond;
pub mod runtime;
pub mod service;
pub mod solver;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
