//! BENCH_*.json baselines and the regression gate.
//!
//! A baseline freezes the full benchmark outcome of one revision:
//!
//! ```json
//! {
//!   "schema": 1,
//!   "rev": "4844671",
//!   "warmup": 1,
//!   "runs": 3,
//!   "results": [
//!     {
//!       "workload": { "name": "darcy-n400", "family": "darcy", ... },
//!       "skr":   { "engine": "skr", "wall": {...}, "solve": {...},
//!                  "counters": { "matvecs": ..., ... },
//!                  "total_iters": ..., "stable": true, ... },
//!       "gmres": { ... },
//!       "time_speedup": 1.8, "iters_speedup": 2.1
//!     }
//!   ]
//! }
//! ```
//!
//! The gate (`skr bench --check`) replays the baseline's own workloads and
//! compares two tiers of evidence:
//!
//! * **deterministic counters** (matvecs, preconditioner applies,
//!   orthogonalization flops, recycle installs, harvests, total
//!   iterations) — compared **exactly**; any increase fails, on any
//!   runner, because they are machine-independent;
//! * **wall-clock medians** — compared within a tolerance
//!   (`--max-regress 5%`), and skipped entirely under `--counters-only`
//!   (the CI default, where runner noise drowns real signal).

use crate::bench::manifest::Manifest;
use crate::bench::runner::WorkloadResult;
use crate::solver::SolveCounters;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Bump when the BENCH json layout changes incompatibly.
pub const SCHEMA_VERSION: u64 = 1;

/// A saved benchmark outcome for one revision.
#[derive(Debug, Clone)]
pub struct Baseline {
    pub schema: u64,
    /// Revision label the baseline was captured at (informational).
    pub rev: String,
    pub warmup: usize,
    pub runs: usize,
    pub results: Vec<WorkloadResult>,
}

impl Baseline {
    pub fn new(rev: &str, m: &Manifest, results: Vec<WorkloadResult>) -> Baseline {
        Baseline {
            schema: SCHEMA_VERSION,
            rev: rev.to_string(),
            warmup: m.warmup,
            runs: m.runs,
            results,
        }
    }

    /// Rebuild the manifest this baseline was produced from, so `--check`
    /// re-runs exactly the recorded workloads (seeds included).
    pub fn manifest(&self) -> Manifest {
        Manifest {
            warmup: self.warmup,
            runs: self.runs,
            workloads: self.results.iter().map(|r| r.workload.clone()).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Num(self.schema as f64)),
            ("rev", Json::Str(self.rev.clone())),
            ("warmup", Json::Num(self.warmup as f64)),
            ("runs", Json::Num(self.runs as f64)),
            ("results", Json::Arr(self.results.iter().map(|r| r.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Baseline> {
        let schema = j.get("schema").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        if schema != SCHEMA_VERSION {
            bail!("baseline schema {schema} unsupported (this build reads {SCHEMA_VERSION})");
        }
        let num = |k: &str, d: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
        let results = j
            .get("results")
            .and_then(|r| r.as_arr())
            .context("baseline missing \"results\"")?
            .iter()
            .map(WorkloadResult::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Baseline {
            schema,
            rev: j.get("rev").and_then(|v| v.as_str()).unwrap_or("unknown").to_string(),
            warmup: num("warmup", 1.0) as usize,
            runs: (num("runs", 1.0) as usize).max(1),
            results,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().dump())
            .with_context(|| format!("writing baseline {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Baseline> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading baseline {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Baseline::from_json(&j).with_context(|| format!("loading {}", path.display()))
    }
}

/// One gate violation, ready to print.
#[derive(Debug, Clone)]
pub struct Regression {
    pub workload: String,
    pub engine: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Regression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} [{}]: {}", self.workload, self.engine, self.detail)
    }
}

/// Parse a time tolerance: `5%` or `0.05` both mean five percent.
pub fn parse_max_regress(s: &str) -> Result<f64> {
    let t = s.trim();
    let v = if let Some(pct) = t.strip_suffix('%') {
        pct.trim().parse::<f64>().map(|p| p / 100.0)
    } else {
        t.parse::<f64>()
    };
    match v {
        Ok(f) if f >= 0.0 && f.is_finite() => Ok(f),
        _ => bail!("invalid --max-regress {s:?} (expected e.g. \"5%\" or \"0.05\")"),
    }
}

fn check_counters(
    out: &mut Vec<Regression>,
    name: &str,
    eng: &'static str,
    base: &SolveCounters,
    cur: &SolveCounters,
    base_iters: u64,
    cur_iters: u64,
) {
    for (&(k, b), &(_, c)) in base.fields().iter().zip(cur.fields().iter()) {
        if c > b {
            out.push(Regression {
                workload: name.to_string(),
                engine: eng,
                detail: format!("counter {k} regressed: {b} -> {c}"),
            });
        }
    }
    if cur_iters > base_iters {
        out.push(Regression {
            workload: name.to_string(),
            engine: eng,
            detail: format!("total_iters regressed: {base_iters} -> {cur_iters}"),
        });
    }
    if base.recycle_installs() > 0 && cur.recycle_installs() == 0 {
        out.push(Regression {
            workload: name.to_string(),
            engine: eng,
            detail: "recycling went inactive (0 subspace installs)".to_string(),
        });
    }
}

/// Compare a fresh run against a baseline. Empty result = gate passes.
///
/// Counters gate exactly; solve-time medians gate within `max_regress`
/// unless `counters_only` (harvests/reseeds/carries shrinking is fine —
/// only *more work* fails).
pub fn check(
    base: &Baseline,
    current: &[WorkloadResult],
    max_regress: f64,
    counters_only: bool,
) -> Vec<Regression> {
    let mut out = Vec::new();
    for b in &base.results {
        let name = &b.workload.name;
        let Some(c) = current.iter().find(|c| c.workload.name == *name) else {
            out.push(Regression {
                workload: name.clone(),
                engine: "-",
                detail: "workload missing from current run".to_string(),
            });
            continue;
        };
        for (eng, br, cr) in [("skr", &b.skr, &c.skr), ("gmres", &b.gmres, &c.gmres)] {
            if !cr.stable {
                out.push(Regression {
                    workload: name.clone(),
                    engine: eng,
                    detail: "counters varied across repeated runs (nondeterminism)".to_string(),
                });
            }
            check_counters(
                &mut out,
                name,
                eng,
                &br.counters,
                &cr.counters,
                br.total_iters,
                cr.total_iters,
            );
            if !counters_only && br.solve.median > 0.0 {
                let limit = br.solve.median * (1.0 + max_regress);
                if cr.solve.median > limit {
                    out.push(Regression {
                        workload: name.clone(),
                        engine: eng,
                        detail: format!(
                            "solve median regressed {:.4}s -> {:.4}s (limit {:.4}s, +{:.0}%)",
                            br.solve.median,
                            cr.solve.median,
                            limit,
                            max_regress * 100.0
                        ),
                    });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::runner::run_workload;
    use crate::pde::FamilyKind;

    fn tiny_results() -> (Manifest, Vec<WorkloadResult>) {
        let mut m = Manifest::quick();
        m.workloads.truncate(1);
        m.warmup = 0;
        m.runs = 1;
        let w = &mut m.workloads[0];
        assert_eq!(w.family, FamilyKind::Darcy);
        w.unknowns = 100;
        w.count = 6;
        let r = run_workload(&m.workloads[0], 0, 1).unwrap();
        (m, vec![r])
    }

    #[test]
    fn baseline_round_trips_and_rebuilds_manifest() {
        let (m, results) = tiny_results();
        let base = Baseline::new("testrev", &m, results);
        let back = Baseline::from_json(&Json::parse(&base.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.rev, "testrev");
        assert_eq!(back.results.len(), 1);
        assert_eq!(back.results[0].skr.counters, base.results[0].skr.counters);
        let m2 = back.manifest();
        assert_eq!(m2.workloads.len(), 1);
        assert_eq!(m2.workloads[0].name, m.workloads[0].name);
        assert_eq!(m2.workloads[0].seed, m.workloads[0].seed);
    }

    #[test]
    fn identical_rerun_passes_gate_and_inflation_fails_it() {
        let (m, results) = tiny_results();
        let base = Baseline::new("t", &m, results.clone());
        assert!(check(&base, &results, 0.05, true).is_empty());

        // Synthetic degradation: the solver suddenly does more work.
        let mut worse = results.clone();
        worse[0].skr.counters.matvecs += 50;
        worse[0].skr.total_iters += 50;
        let regs = check(&base, &worse, 0.05, true);
        assert_eq!(regs.len(), 2, "{regs:?}");
        assert!(regs.iter().any(|r| r.detail.contains("matvecs")));

        // Recycling disabled shows up even if iterations happen to match.
        let mut norec = results.clone();
        norec[0].skr.counters.recycle_reseeds = 0;
        norec[0].skr.counters.recycle_carries = 0;
        let regs = check(&base, &norec, 0.05, true);
        assert!(regs.iter().any(|r| r.detail.contains("recycling went inactive")), "{regs:?}");

        // Missing workload is a failure, not a silent skip.
        let regs = check(&base, &[], 0.05, true);
        assert_eq!(regs.len(), 1);
        assert!(regs[0].detail.contains("missing"));
    }

    #[test]
    fn time_gate_respects_tolerance_and_counters_only() {
        let (m, results) = tiny_results();
        let base = Baseline::new("t", &m, results.clone());
        let mut slow = results.clone();
        slow[0].skr.solve.median = base.results[0].skr.solve.median * 2.0 + 1.0;
        assert!(!check(&base, &slow, 0.05, false).is_empty());
        assert!(check(&base, &slow, 0.05, true).is_empty());
        let mut ok = results.clone();
        ok[0].skr.solve.median = base.results[0].skr.solve.median * 1.01;
        assert!(check(&base, &ok, 0.05, false).is_empty());
    }

    #[test]
    fn max_regress_parses_percent_and_fraction() {
        assert!((parse_max_regress("5%").unwrap() - 0.05).abs() < 1e-12);
        assert!((parse_max_regress("0.05").unwrap() - 0.05).abs() < 1e-12);
        assert!((parse_max_regress(" 12.5 % ").unwrap() - 0.125).abs() < 1e-12);
        assert!(parse_max_regress("-1").is_err());
        assert!(parse_max_regress("lots").is_err());
    }
}
