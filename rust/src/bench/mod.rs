//! `skr bench` — deterministic performance-regression benchmarking.
//!
//! The CI problem with benchmarking a solver is that wall-clock on shared
//! runners is noise. This subsystem splits the evidence in two:
//!
//! * **Deterministic counters** — matvecs, preconditioner applies,
//!   orthogonalization flops, recycle-subspace installs (carries +
//!   reseeds), harvests, total iterations — plumbed out of
//!   [`crate::solver::Workspace`] and summed across the run. The pipeline
//!   shards systems deterministically and each shard solves sequentially,
//!   so these counts are **bit-stable** across repeats and machines; CI
//!   gates on them exactly.
//! * **Wall-clock** — median/IQR over repeated runs, gated only within a
//!   tolerance (`--max-regress`) and only where a human opts in.
//!
//! Modes:
//!
//! ```text
//! skr bench [--quick] [--out BENCH_rev.json] [--rev label]
//! skr bench --check benches/baseline.json [--max-regress 5%] [--counters-only]
//! skr bench --compare BENCH_a.json BENCH_b.json
//! ```
//!
//! Every workload runs under both engines, so each result (and each saved
//! baseline) carries the recycled-vs-GMRES speedup ratio — the paper's
//! headline number — alongside the raw counters.

pub mod baseline;
pub mod manifest;
pub mod report;
pub mod runner;
pub mod stats;

pub use baseline::{check, parse_max_regress, Baseline, Regression, SCHEMA_VERSION};
pub use manifest::{Manifest, Workload};
pub use runner::{run_engine, run_manifest, run_workload, EngineRun, WorkloadResult};
pub use stats::{quantile, summarize, Summary};

use crate::util::args::Args;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// CLI entry point for `skr bench`.
pub fn run(args: &Args) -> Result<()> {
    if let Some(a) = args.get("compare") {
        let b = args
            .positional()
            .first()
            .context("usage: skr bench --compare BENCH_a.json BENCH_b.json")?;
        return compare(Path::new(a), Path::new(b));
    }

    let mut m = select_manifest(args)?;
    if let Some(w) = args.get("warmup") {
        m.warmup = w.parse().context("--warmup")?;
    }
    if let Some(r) = args.get("runs") {
        m.runs = r.parse::<usize>().context("--runs")?.max(1);
    }

    let results = run_manifest(&m, |line| eprintln!("{line}"))?;
    println!("{}", report::results_table(&results));

    if let Some(path) = args.get("check") {
        let base = Baseline::load(Path::new(path))?;
        let max_regress = parse_max_regress(&args.str_or("max-regress", "5%"))?;
        let counters_only = args.flag("counters-only");
        let regs = check(&base, &results, max_regress, counters_only);
        if regs.is_empty() {
            println!(
                "bench gate PASSED against {} ({} workloads, {})",
                path,
                base.results.len(),
                if counters_only { "counters only" } else { "counters + time" }
            );
        } else {
            for r in &regs {
                eprintln!("REGRESSION {r}");
            }
            bail!("bench gate failed: {} regression(s) vs {}", regs.len(), path);
        }
    }

    if let Some(out) = args.get("out") {
        let rev = args.str_or("rev", "unknown");
        let out = PathBuf::from(out);
        if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        Baseline::new(&rev, &m, results).save(&out)?;
        println!("baseline written to {} (rev {rev})", out.display());
    }
    Ok(())
}

/// Pick the workload set: `--check` replays the baseline's own manifest
/// (pinned seeds included) so the comparison is exact; otherwise
/// `--manifest FILE`, `--quick`, or the default suite, optionally filtered
/// by `--workload SUBSTR`.
fn select_manifest(args: &Args) -> Result<Manifest> {
    let mut m = if let Some(path) = args.get("check") {
        Baseline::load(Path::new(path))?.manifest()
    } else if let Some(path) = args.get("manifest") {
        Manifest::from_file(Path::new(path))?
    } else if args.flag("quick") {
        Manifest::quick()
    } else {
        Manifest::default_set()
    };
    if let Some(filter) = args.get("workload") {
        m.retain(filter);
        if m.workloads.is_empty() {
            bail!("--workload {filter:?} matched no workloads");
        }
    }
    Ok(m)
}

fn compare(a: &Path, b: &Path) -> Result<()> {
    let ba = Baseline::load(a)?;
    let bb = Baseline::load(b)?;
    println!("{}", report::compare_table(&ba, &bb));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn manifest_selection_honours_flags() {
        let m = select_manifest(&args("bench --quick")).unwrap();
        assert_eq!(m.workloads.len(), 2);
        let m = select_manifest(&args("bench --quick --workload poisson")).unwrap();
        assert_eq!(m.workloads.len(), 1);
        assert!(m.workloads[0].name.contains("poisson"));
        assert!(select_manifest(&args("bench --quick --workload nosuch")).is_err());
        let m = select_manifest(&args("bench")).unwrap();
        assert!(m.workloads.len() >= 4);
    }
}
