//! Named benchmark workloads with pinned seeds.
//!
//! A [`Workload`] fixes everything that determines a pipeline run — family,
//! grid, count, preconditioner, sort, solver knobs, seed, threads — except
//! the engine: the runner executes each workload under **both** engines so
//! every result carries its recycled-vs-GMRES speedup ratio. The GMRES arm
//! solves in stream order (`--sort none`), mirroring `skr compare`: the
//! baseline the paper speeds up is unsorted restarted GMRES.

use crate::coordinator::{PipelineConfig, SortStrategy};
use crate::pde::FamilyKind;
use crate::precond::PrecondKind;
use crate::solver::Engine;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// One named benchmark configuration (engine-agnostic).
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub family: FamilyKind,
    pub unknowns: usize,
    pub count: usize,
    pub precond: PrecondKind,
    pub sort: SortStrategy,
    pub tol: f64,
    pub m: usize,
    pub k: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Workload {
    fn new(name: &str, family: FamilyKind, unknowns: usize, count: usize) -> Workload {
        Workload {
            name: name.to_string(),
            family,
            unknowns,
            count,
            precond: PrecondKind::Jacobi,
            sort: SortStrategy::Greedy,
            tol: 1e-8,
            m: 30,
            k: 10,
            seed: 7,
            threads: 1,
        }
    }

    /// The pipeline configuration this workload runs under `engine`. The
    /// GMRES baseline arm solves in stream order (no sort), matching
    /// `skr compare`'s paper baseline; no dataset is exported.
    pub fn pipeline_config(&self, engine: Engine) -> PipelineConfig {
        let mut cfg = PipelineConfig::default();
        cfg.family = self.family;
        cfg.unknowns = self.unknowns;
        cfg.count = self.count;
        cfg.engine = engine;
        cfg.precond = self.precond;
        cfg.sort = if engine == Engine::Gmres { SortStrategy::None } else { self.sort };
        cfg.threads = self.threads;
        cfg.seed = self.seed;
        cfg.out_dir = None;
        cfg.solver.tol = self.tol;
        cfg.solver.m = self.m;
        cfg.solver.k = self.k;
        cfg
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("family", Json::Str(self.family.label().to_lowercase())),
            ("n", Json::Num(self.unknowns as f64)),
            ("count", Json::Num(self.count as f64)),
            ("precond", Json::Str(self.precond.label().to_lowercase())),
            ("sort", Json::Str(self.sort.label().to_string())),
            ("tol", Json::Num(self.tol)),
            ("m", Json::Num(self.m as f64)),
            ("k", Json::Num(self.k as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("threads", Json::Num(self.threads as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Workload> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .context("workload missing \"name\"")?
            .to_string();
        let str_or = |k: &str, d: &str| j.get(k).and_then(|v| v.as_str()).unwrap_or(d).to_string();
        let num = |k: &str, d: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
        Ok(Workload {
            family: FamilyKind::parse(&str_or("family", "darcy"))
                .with_context(|| format!("workload {name}"))?,
            unknowns: num("n", 900.0) as usize,
            count: num("count", 24.0) as usize,
            precond: PrecondKind::parse(&str_or("precond", "jacobi"))
                .with_context(|| format!("workload {name}"))?,
            sort: SortStrategy::parse(&str_or("sort", "greedy"))
                .with_context(|| format!("workload {name}"))?,
            tol: num("tol", 1e-8),
            m: num("m", 30.0) as usize,
            k: num("k", 10.0) as usize,
            seed: num("seed", 7.0) as u64,
            threads: (num("threads", 1.0) as usize).max(1),
            name,
        })
    }
}

/// A set of workloads plus the repetition protocol.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Unmeasured runs per (workload, engine) to warm caches / page in.
    pub warmup: usize,
    /// Measured runs per (workload, engine); counters must not vary.
    pub runs: usize,
    pub workloads: Vec<Workload>,
}

impl Manifest {
    /// The default suite: one workload per PDE family at CI-feasible sizes,
    /// Darcy largest (the paper's headline family).
    pub fn default_set() -> Manifest {
        let mut helmholtz = Workload::new("helmholtz-n400", FamilyKind::Helmholtz, 400, 16);
        helmholtz.precond = PrecondKind::Ilu;
        Manifest {
            warmup: 1,
            runs: 3,
            workloads: vec![
                Workload::new("darcy-n2500", FamilyKind::Darcy, 2500, 16),
                Workload::new("thermal-n900", FamilyKind::Thermal, 900, 24),
                Workload::new("poisson-n900", FamilyKind::Poisson, 900, 24),
                helmholtz,
            ],
        }
    }

    /// Small suite for CI gating: fast, still exercises recycling.
    pub fn quick() -> Manifest {
        Manifest {
            warmup: 1,
            runs: 3,
            workloads: vec![
                Workload::new("darcy-n400", FamilyKind::Darcy, 400, 12),
                Workload::new("poisson-n400", FamilyKind::Poisson, 400, 12),
            ],
        }
    }

    /// Keep only workloads whose name contains `filter` (case-insensitive).
    pub fn retain(&mut self, filter: &str) {
        let f = filter.to_ascii_lowercase();
        self.workloads.retain(|w| w.name.to_ascii_lowercase().contains(&f));
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("warmup", Json::Num(self.warmup as f64)),
            ("runs", Json::Num(self.runs as f64)),
            ("workloads", Json::Arr(self.workloads.iter().map(|w| w.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let num = |k: &str, d: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
        let workloads = j
            .get("workloads")
            .and_then(|w| w.as_arr())
            .context("manifest missing \"workloads\"")?
            .iter()
            .map(Workload::from_json)
            .collect::<Result<Vec<_>>>()?;
        if workloads.is_empty() {
            bail!("manifest has no workloads");
        }
        Ok(Manifest {
            warmup: num("warmup", 1.0) as usize,
            runs: (num("runs", 3.0) as usize).max(1),
            workloads,
        })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Manifest::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_round_trips_through_json() {
        for w in Manifest::default_set().workloads {
            let j = Json::parse(&w.to_json().dump()).unwrap();
            let back = Workload::from_json(&j).unwrap();
            assert_eq!(back.name, w.name);
            assert_eq!(back.family, w.family);
            assert_eq!(back.unknowns, w.unknowns);
            assert_eq!(back.count, w.count);
            assert_eq!(back.precond, w.precond);
            assert_eq!(back.sort, w.sort);
            assert_eq!(back.tol, w.tol);
            assert_eq!(back.m, w.m);
            assert_eq!(back.k, w.k);
            assert_eq!(back.seed, w.seed);
            assert_eq!(back.threads, w.threads);
        }
    }

    #[test]
    fn manifest_round_trips_and_filters() {
        let m = Manifest::default_set();
        let j = Json::parse(&m.to_json().dump()).unwrap();
        let back = Manifest::from_json(&j).unwrap();
        assert_eq!(back.warmup, m.warmup);
        assert_eq!(back.runs, m.runs);
        assert_eq!(back.workloads.len(), m.workloads.len());

        let mut filtered = back;
        filtered.retain("DARCY");
        assert_eq!(filtered.workloads.len(), 1);
        assert_eq!(filtered.workloads[0].name, "darcy-n2500");
    }

    #[test]
    fn gmres_arm_runs_unsorted() {
        let w = &Manifest::quick().workloads[0];
        let skr = w.pipeline_config(Engine::SkrRecycle);
        let gm = w.pipeline_config(Engine::Gmres);
        assert_eq!(skr.sort, SortStrategy::Greedy);
        assert_eq!(gm.sort, SortStrategy::None);
        assert_eq!(skr.seed, gm.seed);
        assert_eq!(skr.solver.tol, gm.solver.tol);
        assert!(skr.out_dir.is_none() && gm.out_dir.is_none());
    }
}
