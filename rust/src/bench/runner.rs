//! Executes workloads and collects timing + deterministic counters.
//!
//! Each workload runs under both engines: SKR (recycling, sorted stream)
//! and the GMRES baseline (stream order). Per engine we do `warmup`
//! unmeasured runs, then `runs` measured ones. Wall-clock is summarized
//! with median/IQR; the deterministic counters must be **identical**
//! across the measured runs — the pipeline shards systems
//! deterministically and solves each shard sequentially, so any variation
//! means nondeterminism crept in and the run is flagged unstable.

use crate::bench::manifest::{Manifest, Workload};
use crate::bench::stats::{summarize, Summary};
use crate::coordinator::Pipeline;
use crate::solver::{Engine, SolveCounters};
use crate::util::json::Json;
use anyhow::{Context, Result};

/// Measured behaviour of one workload under one engine.
#[derive(Debug, Clone)]
pub struct EngineRun {
    pub engine: Engine,
    /// End-to-end pipeline wall seconds per measured run.
    pub wall: Summary,
    /// Solve-stage seconds (sum over systems) per measured run.
    pub solve: Summary,
    /// Deterministic op counters from the first measured run.
    pub counters: SolveCounters,
    pub total_iters: u64,
    pub breakdowns: u64,
    pub max_iter_hits: u64,
    /// True iff every measured run reproduced the same counters + iters.
    pub stable: bool,
}

impl EngineRun {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("engine", Json::Str(self.engine.label().to_lowercase())),
            ("wall", self.wall.to_json()),
            ("solve", self.solve.to_json()),
            ("counters", counters_to_json(&self.counters)),
            ("total_iters", Json::Num(self.total_iters as f64)),
            ("breakdowns", Json::Num(self.breakdowns as f64)),
            ("max_iter_hits", Json::Num(self.max_iter_hits as f64)),
            ("stable", Json::Bool(self.stable)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<EngineRun> {
        let label = j.get("engine").and_then(|v| v.as_str()).unwrap_or("skr");
        let num = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        Ok(EngineRun {
            engine: Engine::parse(label)?,
            wall: j.get("wall").map(Summary::from_json).unwrap_or_default(),
            solve: j.get("solve").map(Summary::from_json).unwrap_or_default(),
            counters: j.get("counters").map(counters_from_json).unwrap_or_default(),
            total_iters: num("total_iters"),
            breakdowns: num("breakdowns"),
            max_iter_hits: num("max_iter_hits"),
            stable: matches!(j.get("stable"), Some(Json::Bool(true))),
        })
    }
}

pub fn counters_to_json(c: &SolveCounters) -> Json {
    Json::obj(c.fields().iter().map(|&(k, v)| (k, Json::Num(v as f64))).collect())
}

pub fn counters_from_json(j: &Json) -> SolveCounters {
    let num = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    SolveCounters {
        matvecs: num("matvecs"),
        precond_applies: num("precond_applies"),
        ortho_flops: num("ortho_flops"),
        recycle_reseeds: num("recycle_reseeds"),
        recycle_carries: num("recycle_carries"),
        harvests: num("harvests"),
    }
}

/// One workload measured under both engines.
#[derive(Debug, Clone)]
pub struct WorkloadResult {
    pub workload: Workload,
    pub skr: EngineRun,
    pub gmres: EngineRun,
}

impl WorkloadResult {
    /// GMRES-baseline solve time over SKR solve time (medians); > 1 means
    /// recycling is faster. 0 when the SKR median is degenerate.
    pub fn time_speedup(&self) -> f64 {
        if self.skr.solve.median > 0.0 {
            self.gmres.solve.median / self.skr.solve.median
        } else {
            0.0
        }
    }

    /// GMRES total iterations over SKR total iterations — the
    /// machine-independent version of the speedup.
    pub fn iters_speedup(&self) -> f64 {
        if self.skr.total_iters > 0 {
            self.gmres.total_iters as f64 / self.skr.total_iters as f64
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", self.workload.to_json()),
            ("skr", self.skr.to_json()),
            ("gmres", self.gmres.to_json()),
            ("time_speedup", Json::Num(self.time_speedup())),
            ("iters_speedup", Json::Num(self.iters_speedup())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<WorkloadResult> {
        Ok(WorkloadResult {
            workload: Workload::from_json(j.get("workload").context("result missing workload")?)?,
            skr: EngineRun::from_json(j.get("skr").context("result missing skr run")?)?,
            gmres: EngineRun::from_json(j.get("gmres").context("result missing gmres run")?)?,
        })
    }
}

/// Run `w` under one engine: `warmup` unmeasured runs then `runs` measured.
pub fn run_engine(w: &Workload, engine: Engine, warmup: usize, runs: usize) -> Result<EngineRun> {
    let cfg = w.pipeline_config(engine);
    for _ in 0..warmup {
        Pipeline::new(cfg.clone())
            .run()
            .with_context(|| format!("warmup of {} under {}", w.name, engine.label()))?;
    }
    let mut wall = Vec::with_capacity(runs);
    let mut solve = Vec::with_capacity(runs);
    let mut first: Option<(SolveCounters, u64)> = None;
    let mut stable = true;
    let mut breakdowns = 0;
    let mut max_iter_hits = 0;
    for _ in 0..runs.max(1) {
        let res = Pipeline::new(cfg.clone())
            .run()
            .with_context(|| format!("running {} under {}", w.name, engine.label()))?;
        wall.push(res.metrics.wall_seconds);
        solve.push(res.metrics.solve_seconds);
        breakdowns = res.metrics.breakdowns as u64;
        max_iter_hits = res.metrics.max_iter_hits as u64;
        let now = (res.metrics.counters, res.metrics.total_iters as u64);
        match &first {
            None => first = Some(now),
            Some(prev) => stable &= *prev == now,
        }
    }
    let (counters, total_iters) = first.unwrap_or_default();
    Ok(EngineRun {
        engine,
        wall: summarize(&wall),
        solve: summarize(&solve),
        counters,
        total_iters,
        breakdowns,
        max_iter_hits,
        stable,
    })
}

/// Run one workload under both engines.
pub fn run_workload(w: &Workload, warmup: usize, runs: usize) -> Result<WorkloadResult> {
    Ok(WorkloadResult {
        workload: w.clone(),
        skr: run_engine(w, Engine::SkrRecycle, warmup, runs)?,
        gmres: run_engine(w, Engine::Gmres, warmup, runs)?,
    })
}

/// Run every workload in the manifest, reporting progress via `progress`.
pub fn run_manifest(m: &Manifest, mut progress: impl FnMut(&str)) -> Result<Vec<WorkloadResult>> {
    let mut out = Vec::with_capacity(m.workloads.len());
    for (i, w) in m.workloads.iter().enumerate() {
        progress(&format!(
            "[{}/{}] {} (n={}, count={}, {} runs + {} warmup per engine)",
            i + 1,
            m.workloads.len(),
            w.name,
            w.unknowns,
            w.count,
            m.runs,
            m.warmup
        ));
        let r = run_workload(w, m.warmup, m.runs)?;
        if !r.skr.stable || !r.gmres.stable {
            progress(&format!("warning: {} produced unstable counters", w.name));
        }
        out.push(r);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::FamilyKind;

    fn tiny() -> Workload {
        let mut m = Manifest::quick();
        let mut w = m.workloads.remove(0);
        assert_eq!(w.family, FamilyKind::Darcy);
        w.unknowns = 100;
        w.count = 6;
        w
    }

    #[test]
    fn engine_run_counters_are_stable_and_round_trip() {
        let w = tiny();
        let r = run_engine(&w, Engine::SkrRecycle, 0, 2).unwrap();
        assert!(r.stable, "counters drifted across identical runs");
        assert!(r.counters.matvecs > 0 && r.total_iters > 0);
        assert!(r.counters.harvests > 0, "recycling never harvested: {:?}", r.counters);

        let back = EngineRun::from_json(&Json::parse(&r.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.engine, r.engine);
        assert_eq!(back.counters, r.counters);
        assert_eq!(back.total_iters, r.total_iters);
        assert_eq!(back.stable, r.stable);
        assert_eq!(back.solve.median, r.solve.median);
    }

    #[test]
    fn workload_result_reports_iteration_speedup() {
        let w = tiny();
        let r = run_workload(&w, 0, 1).unwrap();
        assert!(r.gmres.counters.recycle_installs() == 0);
        assert!(r.skr.counters.recycle_installs() > 0);
        assert!(
            r.iters_speedup() > 1.0,
            "recycling should beat GMRES on iterations: {} vs {}",
            r.skr.total_iters,
            r.gmres.total_iters
        );
        let back = WorkloadResult::from_json(&Json::parse(&r.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.skr.counters, r.skr.counters);
        assert_eq!(back.workload.name, r.workload.name);
    }
}
