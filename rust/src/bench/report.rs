//! Human-readable rendering of benchmark results and baseline diffs.

use crate::bench::baseline::Baseline;
use crate::bench::runner::WorkloadResult;
use crate::util::table::Table;

fn ms(seconds: f64) -> String {
    format!("{:.1}", seconds * 1e3)
}

/// Table for a fresh `skr bench` run: per-workload medians, counters, and
/// the recycled-vs-GMRES speedup ratios.
pub fn results_table(results: &[WorkloadResult]) -> String {
    let mut t = Table::new(
        "skr bench",
        &[
            "workload",
            "skr ms (med)",
            "gmres ms (med)",
            "skr iters",
            "gmres iters",
            "matvecs",
            "speedup t/it",
            "stable",
        ],
    );
    for r in results {
        t.row(vec![
            r.workload.name.clone(),
            ms(r.skr.solve.median),
            ms(r.gmres.solve.median),
            r.skr.total_iters.to_string(),
            r.gmres.total_iters.to_string(),
            r.skr.counters.matvecs.to_string(),
            format!("{:.2}/{:.2}", r.time_speedup(), r.iters_speedup()),
            if r.skr.stable && r.gmres.stable { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

fn delta_pct(old: f64, new: f64) -> String {
    if old > 0.0 {
        format!("{:+.1}%", (new / old - 1.0) * 100.0)
    } else {
        "n/a".to_string()
    }
}

/// Table for `skr bench --compare a.json b.json`: per-workload deltas
/// between two saved baselines (a = reference, b = candidate).
pub fn compare_table(a: &Baseline, b: &Baseline) -> String {
    let title = format!("bench compare: {} -> {}", a.rev, b.rev);
    let mut t = Table::new(
        &title,
        &["workload", "skr ms a->b", "Δtime", "skr iters a->b", "Δmatvecs", "speedup a->b"],
    );
    for ra in &a.results {
        let name = &ra.workload.name;
        let Some(rb) = b.results.iter().find(|r| r.workload.name == *name) else {
            t.row(vec![
                name.clone(),
                format!("{} -> gone", ms(ra.skr.solve.median)),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        };
        t.row(vec![
            name.clone(),
            format!("{} -> {}", ms(ra.skr.solve.median), ms(rb.skr.solve.median)),
            delta_pct(ra.skr.solve.median, rb.skr.solve.median),
            format!("{} -> {}", ra.skr.total_iters, rb.skr.total_iters),
            format!("{:+}", rb.skr.counters.matvecs as i64 - ra.skr.counters.matvecs as i64),
            format!("{:.2} -> {:.2}", ra.time_speedup(), rb.time_speedup()),
        ]);
    }
    for rb in &b.results {
        if !a.results.iter().any(|r| r.workload.name == rb.workload.name) {
            t.row(vec![
                rb.workload.name.clone(),
                format!("new -> {}", ms(rb.skr.solve.median)),
                "-".into(),
                format!("new -> {}", rb.skr.total_iters),
                "-".into(),
                format!("new -> {:.2}", rb.time_speedup()),
            ]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::manifest::Manifest;
    use crate::bench::runner::{EngineRun, WorkloadResult};
    use crate::bench::stats::summarize;
    use crate::solver::{Engine, SolveCounters};

    fn fake_result(name: &str, skr_iters: u64, gmres_iters: u64) -> WorkloadResult {
        let mut m = Manifest::quick();
        let mut w = m.workloads.remove(0);
        w.name = name.to_string();
        let run = |engine, iters: u64, secs: f64| EngineRun {
            engine,
            wall: summarize(&[secs * 2.0]),
            solve: summarize(&[secs]),
            counters: SolveCounters { matvecs: iters + 2, ..Default::default() },
            total_iters: iters,
            breakdowns: 0,
            max_iter_hits: 0,
            stable: true,
        };
        WorkloadResult {
            workload: w,
            skr: run(Engine::SkrRecycle, skr_iters, 0.010),
            gmres: run(Engine::Gmres, gmres_iters, 0.025),
        }
    }

    #[test]
    fn results_table_shows_speedup_and_stability() {
        let out = results_table(&[fake_result("darcy-x", 100, 250)]);
        assert!(out.contains("darcy-x"));
        assert!(out.contains("2.50/2.50"), "{out}");
        assert!(out.contains("yes"));
    }

    #[test]
    fn compare_table_reports_deltas_and_membership() {
        let m = Manifest::quick();
        let olds = vec![fake_result("w1", 100, 200), fake_result("w2", 50, 100)];
        let a = Baseline::new("aaa", &m, olds);
        let mut newer = vec![fake_result("w1", 110, 200), fake_result("w3", 10, 30)];
        newer[0].skr.counters.matvecs = 150;
        let b = Baseline::new("bbb", &m, newer);
        let out = compare_table(&a, &b);
        assert!(out.contains("aaa -> bbb"));
        assert!(out.contains("100 -> 110"), "{out}");
        assert!(out.contains("+48"), "{out}");
        assert!(out.contains("gone"), "{out}");
        assert!(out.contains("new -> 10"), "{out}");
    }
}
