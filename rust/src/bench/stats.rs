//! Robust summary statistics for repeated benchmark runs.
//!
//! Wall-clock samples from a CI runner are noisy and occasionally
//! heavy-tailed (one run lands on a busy core), so the benchmark reports
//! median and IQR rather than mean/stddev. Quantiles use linear
//! interpolation between order statistics (numpy's default, R type 7).

use crate::util::json::Json;

/// Five-number-style summary of a sample set, plus the raw samples so a
/// saved baseline can be re-analyzed later.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Summary {
    pub median: f64,
    pub q1: f64,
    pub q3: f64,
    pub min: f64,
    pub max: f64,
    /// The sorted samples the quantiles were computed from.
    pub samples: Vec<f64>,
}

impl Summary {
    /// Interquartile range — the noise band the time gate is calibrated to.
    pub fn iqr(&self) -> f64 {
        self.q3 - self.q1
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("median", Json::Num(self.median)),
            ("q1", Json::Num(self.q1)),
            ("q3", Json::Num(self.q3)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("samples", Json::Arr(self.samples.iter().map(|&s| Json::Num(s)).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Summary {
        let num = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let samples = j
            .get("samples")
            .and_then(|s| s.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default();
        Summary {
            median: num("median"),
            q1: num("q1"),
            q3: num("q3"),
            min: num("min"),
            max: num("max"),
            samples,
        }
    }
}

/// q-quantile of a **sorted** slice via linear interpolation between order
/// statistics (R type 7 / numpy default). Empty input yields 0.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Summarize a sample set (any order; NaNs sort last and are the caller's
/// bug, not this function's).
pub fn summarize(samples: &[f64]) -> Summary {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    Summary {
        median: quantile(&s, 0.5),
        q1: quantile(&s, 0.25),
        q3: quantile(&s, 0.75),
        min: s.first().copied().unwrap_or(0.0),
        max: s.last().copied().unwrap_or(0.0),
        samples: s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_iqr_on_known_samples() {
        // Odd count: exact middle element.
        let s = summarize(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // q1/q3 interpolate: positions 0.5 and 1.5 over [1,3,5].
        assert!((s.q1 - 2.0).abs() < 1e-12);
        assert!((s.q3 - 4.0).abs() < 1e-12);
        assert!((s.iqr() - 2.0).abs() < 1e-12);

        // Even count: median interpolates between the middle pair.
        let s = summarize(&[4.0, 1.0, 3.0, 2.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert!((s.q1 - 1.75).abs() < 1e-12);
        assert!((s.q3 - 3.25).abs() < 1e-12);

        // Classic textbook set: 1..=9 has median 5, q1 3, q3 7.
        let s = summarize(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.q1, 3.0);
        assert_eq!(s.q3, 7.0);
        assert_eq!(s.iqr(), 4.0);
    }

    #[test]
    fn degenerate_sample_sets() {
        let s = summarize(&[]);
        assert_eq!(s.median, 0.0);
        assert_eq!(s.iqr(), 0.0);
        let s = summarize(&[2.5]);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.q1, 2.5);
        assert_eq!(s.q3, 2.5);
        assert_eq!(s.min, 2.5);
        assert_eq!(s.max, 2.5);
    }

    #[test]
    fn json_round_trip_preserves_summary() {
        let s = summarize(&[0.25, 0.5, 0.125, 0.75]);
        let j = s.to_json();
        let back = Summary::from_json(&Json::parse(&j.dump()).unwrap());
        assert_eq!(s, back);
    }
}
