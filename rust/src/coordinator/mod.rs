//! L3 coordinator — the paper's system contribution: the streaming
//! data-generation pipeline with similarity **sorting** and per-worker
//! **Krylov recycling**, plus the scheduler, metrics, dataset assembly and
//! the δ-subspace instrumentation behind the ablation study.

pub mod config;
pub mod control;
pub mod dataset;
pub mod delta;
pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod sorter;

pub use config::PipelineConfig;
pub use control::{Cancelled, ProgressSnapshot, RunControl};
pub use pipeline::{Pipeline, PipelineResult, RunPlan, WorkerReport};
pub use sorter::SortStrategy;
