//! The Sorting Algorithm (paper §4.1, Algorithm 1) and its scalable
//! variants (Appendix E.2.2): serialize the stream of linear systems so
//! consecutive systems have highly similar parameter matrices, maximizing
//! what the Krylov recycler can reuse.

use crate::util::prng::Rng;

/// Sorting strategy for the solve order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortStrategy {
    /// Keep the generation order (the "no sort" ablation arm).
    None,
    /// Greedy nearest-neighbour chain over Frobenius distances (Alg. 1).
    Greedy,
    /// Split into groups of `group_size` (by a cheap space-filling key),
    /// greedy-sort within each group, concatenate — the paper's
    /// cost-reduction for 10³–10⁵ systems.
    GroupedGreedy { group_size: usize },
    /// Pure Hilbert-curve order on a 2-D PCA-like projection (the paper's
    /// "FFT dimension reduction + fractal division" analogue).
    Hilbert,
    /// Random shuffle (adversarial ablation arm).
    Shuffle,
}

impl SortStrategy {
    pub fn parse(s: &str) -> anyhow::Result<SortStrategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "nosort" => SortStrategy::None,
            "greedy" | "sort" => SortStrategy::Greedy,
            "grouped" => SortStrategy::GroupedGreedy { group_size: 1000 },
            "hilbert" => SortStrategy::Hilbert,
            "shuffle" => SortStrategy::Shuffle,
            other => anyhow::bail!("unknown sort strategy {other:?}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            SortStrategy::None => "none",
            SortStrategy::Greedy => "greedy",
            SortStrategy::GroupedGreedy { .. } => "grouped",
            SortStrategy::Hilbert => "hilbert",
            SortStrategy::Shuffle => "shuffle",
        }
    }
}

/// Squared Frobenius distance between two flattened parameter matrices.
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Produce the solve order for parameter vectors `params` (one per system).
pub fn sort_order(params: &[Vec<f64>], strategy: SortStrategy, seed: u64) -> Vec<usize> {
    let n = params.len();
    match strategy {
        SortStrategy::None => (0..n).collect(),
        SortStrategy::Shuffle => {
            let mut rng = Rng::new(seed);
            rng.permutation(n)
        }
        SortStrategy::Greedy => greedy_chain(params, &(0..n).collect::<Vec<_>>()),
        SortStrategy::GroupedGreedy { group_size } => {
            let groups = split_by_projection(params, group_size.max(2));
            let mut out = Vec::with_capacity(n);
            for g in groups {
                out.extend(greedy_chain(params, &g));
            }
            out
        }
        SortStrategy::Hilbert => hilbert_order(params),
    }
}

/// Algorithm 1: start at the first element, repeatedly append the unvisited
/// system with minimal Frobenius distance to the current one.
fn greedy_chain(params: &[Vec<f64>], ids: &[usize]) -> Vec<usize> {
    if ids.is_empty() {
        return Vec::new();
    }
    let mut remaining: Vec<usize> = ids[1..].to_vec();
    let mut order = Vec::with_capacity(ids.len());
    let mut cur = ids[0];
    order.push(cur);
    while !remaining.is_empty() {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (slot, &j) in remaining.iter().enumerate() {
            let d = dist2(&params[cur], &params[j]);
            if d < best_d {
                best_d = d;
                best = slot;
            }
        }
        cur = remaining.swap_remove(best);
        order.push(cur);
    }
    order
}

/// Cheap grouping: project each parameter vector onto its dominant
/// variation direction (first two "frequency" components — a small DFT of
/// the flattened parameters, the paper's FFT dimension-reduction), sort by
/// the first component, then chunk.
fn split_by_projection(params: &[Vec<f64>], group_size: usize) -> Vec<Vec<usize>> {
    let keys: Vec<(f64, usize)> = params
        .iter()
        .enumerate()
        .map(|(i, p)| (projection2(p).0, i))
        .collect();
    let mut sorted = keys;
    sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    sorted
        .chunks(group_size)
        .map(|c| c.iter().map(|&(_, i)| i).collect())
        .collect()
}

/// First two low-frequency DFT components of a parameter vector — a 2-D
/// sketch preserving coarse similarity.
fn projection2(p: &[f64]) -> (f64, f64) {
    let n = p.len().max(1) as f64;
    let mut c1 = 0.0;
    let mut c2 = 0.0;
    for (t, &v) in p.iter().enumerate() {
        let ph = 2.0 * std::f64::consts::PI * t as f64 / n;
        c1 += v * ph.cos();
        c2 += v * ph.sin();
    }
    let mean: f64 = p.iter().sum::<f64>() / n;
    // (mean, first-harmonic magnitude-ish): robust cheap key pair.
    (mean, (c1 * c1 + c2 * c2).sqrt())
}

/// Order by position along a Hilbert curve over the 2-D projection.
fn hilbert_order(params: &[Vec<f64>]) -> Vec<usize> {
    let proj: Vec<(f64, f64)> = params.iter().map(|p| projection2(p)).collect();
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &proj {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    let side = 1u32 << 10; // 1024×1024 resolution
    let scale = |v: f64, lo: f64, hi: f64| {
        if hi - lo < 1e-300 {
            0u32
        } else {
            (((v - lo) / (hi - lo)) * (side - 1) as f64) as u32
        }
    };
    let mut keyed: Vec<(u64, usize)> = proj
        .iter()
        .enumerate()
        .map(|(i, &(x, y))| {
            (hilbert_d(side, scale(x, xmin, xmax), scale(y, ymin, ymax)), i)
        })
        .collect();
    keyed.sort_by_key(|&(d, _)| d);
    keyed.into_iter().map(|(_, i)| i).collect()
}

/// Hilbert curve (x,y) → distance, classic signed-arithmetic transform
/// (Wikipedia `xy2d`).
fn hilbert_d(side: u32, x: u32, y: u32) -> u64 {
    let (mut x, mut y) = (x as i64, y as i64);
    let mut d: u64 = 0;
    let mut s = (side / 2) as i64;
    while s > 0 {
        let rx = i64::from((x & s) > 0);
        let ry = i64::from((y & s) > 0);
        d += (s as u64) * (s as u64) * ((3 * rx) ^ ry) as u64;
        // Rotate the quadrant.
        if ry == 0 {
            if rx == 1 {
                x = s - 1 - x;
                y = s - 1 - y;
            }
            std::mem::swap(&mut x, &mut y);
        }
        s /= 2;
    }
    d
}

/// Mean consecutive-pair parameter distance along an order — the quantity
/// sorting minimizes; used by tests and the ablation bench.
pub fn chain_cost(params: &[Vec<f64>], order: &[usize]) -> f64 {
    if order.len() < 2 {
        return 0.0;
    }
    order
        .windows(2)
        .map(|w| dist2(&params[w[0]], &params[w[1]]).sqrt())
        .sum::<f64>()
        / (order.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normals(dim)).collect()
    }

    #[test]
    fn orders_are_permutations() {
        let params = cloud(50, 8, 1);
        for s in [
            SortStrategy::None,
            SortStrategy::Greedy,
            SortStrategy::GroupedGreedy { group_size: 16 },
            SortStrategy::Hilbert,
            SortStrategy::Shuffle,
        ] {
            let order = sort_order(&params, s, 3);
            let mut seen = vec![false; 50];
            for &i in &order {
                assert!(!seen[i], "{s:?} repeats {i}");
                seen[i] = true;
            }
            assert!(seen.iter().all(|&x| x), "{s:?} incomplete");
        }
    }

    #[test]
    fn greedy_beats_unsorted_chain_cost() {
        let params = cloud(200, 6, 2);
        let unsorted = sort_order(&params, SortStrategy::None, 0);
        let greedy = sort_order(&params, SortStrategy::Greedy, 0);
        let c0 = chain_cost(&params, &unsorted);
        let c1 = chain_cost(&params, &greedy);
        assert!(c1 < c0, "greedy {c1} vs none {c0}");
    }

    #[test]
    fn greedy_recovers_line_structure() {
        // Points on a line, shuffled: greedy should walk it end to end,
        // giving chain cost close to the minimal spacing.
        let mut params: Vec<Vec<f64>> = (0..64).map(|i| vec![i as f64]).collect();
        let mut rng = Rng::new(9);
        rng.shuffle(&mut params);
        let order = sort_order(&params, SortStrategy::Greedy, 0);
        let cost = chain_cost(&params, &order);
        assert!(cost <= 2.0, "cost {cost}"); // perfect walk costs 1.0
    }

    #[test]
    fn grouped_is_close_to_greedy_on_clusters() {
        // Two tight clusters: grouped-greedy must not interleave them badly.
        let mut params = Vec::new();
        let mut rng = Rng::new(4);
        for c in 0..2 {
            for _ in 0..30 {
                let base = c as f64 * 100.0;
                params.push(vec![base + 0.1 * rng.normal(), base + 0.1 * rng.normal()]);
            }
        }
        let grouped = sort_order(&params, SortStrategy::GroupedGreedy { group_size: 30 }, 0);
        let cost = chain_cost(&params, &grouped);
        // One inter-cluster hop of ~141 over 59 hops ⇒ mean ≲ 3.
        assert!(cost < 5.0, "cost {cost}");
    }

    #[test]
    fn hilbert_beats_shuffle() {
        let params = cloud(300, 2, 8);
        let h = chain_cost(&params, &sort_order(&params, SortStrategy::Hilbert, 0));
        let s = chain_cost(&params, &sort_order(&params, SortStrategy::Shuffle, 0));
        assert!(h < s, "hilbert {h} vs shuffle {s}");
    }
}
