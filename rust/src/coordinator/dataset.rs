//! Dataset assembly: collect (input field, solution) pairs indexed by their
//! original stream id and export NumPy `.npy` arrays plus a JSON meta file —
//! directly loadable by the python FNO pipeline and by `no::data`.

use crate::util::json::Json;
use crate::util::npy::{self, NpyArray};
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// In-memory accumulation buffer for a dataset being generated out of order.
pub struct DatasetWriter {
    dir: PathBuf,
    count: usize,
    input_dim: usize,
    sol_dim: usize,
    inputs: Vec<f64>,
    solutions: Vec<f64>,
    filled: Vec<bool>,
    /// Grid side for reshaping on the python side (0 = unstructured).
    field_side: usize,
}

/// What was written where.
#[derive(Debug, Clone)]
pub struct DatasetSummary {
    pub dir: PathBuf,
    pub count: usize,
    pub input_dim: usize,
    pub sol_dim: usize,
}

impl DatasetWriter {
    pub fn new(dir: &Path, count: usize, input_dim: usize, sol_dim: usize, field_side: usize) -> DatasetWriter {
        DatasetWriter {
            dir: dir.to_path_buf(),
            count,
            input_dim,
            sol_dim,
            inputs: vec![0.0; count * input_dim],
            solutions: vec![0.0; count * sol_dim],
            filled: vec![false; count],
            field_side,
        }
    }

    /// Record sample `id` (original stream position).
    pub fn put(&mut self, id: usize, input: &[f64], solution: &[f64]) -> Result<()> {
        if id >= self.count {
            bail!("sample id {id} out of range {}", self.count);
        }
        if input.len() != self.input_dim || solution.len() != self.sol_dim {
            bail!(
                "dim mismatch for id {id}: input {} (want {}), sol {} (want {})",
                input.len(),
                self.input_dim,
                solution.len(),
                self.sol_dim
            );
        }
        if self.filled[id] {
            bail!("sample id {id} written twice");
        }
        self.inputs[id * self.input_dim..(id + 1) * self.input_dim].copy_from_slice(input);
        self.solutions[id * self.sol_dim..(id + 1) * self.sol_dim].copy_from_slice(solution);
        self.filled[id] = true;
        Ok(())
    }

    pub fn complete(&self) -> bool {
        self.filled.iter().all(|&f| f)
    }

    /// Write `inputs.npy`, `solutions.npy` and `meta.json`.
    pub fn finalize(self, family: &str, extra: Vec<(&str, Json)>) -> Result<DatasetSummary> {
        if !self.complete() {
            let missing = self.filled.iter().filter(|&&f| !f).count();
            bail!("dataset incomplete: {missing} of {} samples missing", self.count);
        }
        std::fs::create_dir_all(&self.dir)?;
        npy::write(
            &self.dir.join("inputs.npy"),
            &NpyArray::f64(vec![self.count, self.input_dim], self.inputs),
        )?;
        npy::write(
            &self.dir.join("solutions.npy"),
            &NpyArray::f64(vec![self.count, self.sol_dim], self.solutions),
        )?;
        let mut pairs = vec![
            ("family", Json::Str(family.to_string())),
            ("count", Json::Num(self.count as f64)),
            ("input_dim", Json::Num(self.input_dim as f64)),
            ("sol_dim", Json::Num(self.sol_dim as f64)),
            ("field_side", Json::Num(self.field_side as f64)),
        ];
        pairs.extend(extra);
        std::fs::write(self.dir.join("meta.json"), Json::obj(pairs).dump())?;
        Ok(DatasetSummary {
            dir: self.dir,
            count: self.count,
            input_dim: self.input_dim,
            sol_dim: self.sol_dim,
        })
    }
}

/// Load a dataset written by [`DatasetWriter`] (used by the FNO trainer).
pub fn load(dir: &Path) -> Result<(NpyArray, NpyArray, Json)> {
    let inputs = npy::read(&dir.join("inputs.npy"))?;
    let solutions = npy::read(&dir.join("solutions.npy"))?;
    let meta = Json::parse(&std::fs::read_to_string(dir.join("meta.json"))?)?;
    Ok((inputs, solutions, meta))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_out_of_order() {
        let dir = std::env::temp_dir().join("skr_ds_test_1");
        let _ = std::fs::remove_dir_all(&dir);
        let mut w = DatasetWriter::new(&dir, 3, 2, 4, 2);
        w.put(2, &[5.0, 6.0], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        w.put(0, &[1.0, 2.0], &[0.0; 4]).unwrap();
        w.put(1, &[3.0, 4.0], &[9.0; 4]).unwrap();
        assert!(w.complete());
        let s = w.finalize("darcy", vec![]).unwrap();
        assert_eq!(s.count, 3);
        let (ins, sols, meta) = load(&dir).unwrap();
        assert_eq!(ins.shape, vec![3, 2]);
        assert_eq!(sols.shape, vec![3, 4]);
        assert_eq!(&ins.data[4..6], &[5.0, 6.0]);
        assert_eq!(meta.get("family").unwrap().as_str(), Some("darcy"));
    }

    #[test]
    fn rejects_double_write_and_incomplete() {
        let dir = std::env::temp_dir().join("skr_ds_test_2");
        let mut w = DatasetWriter::new(&dir, 2, 1, 1, 0);
        w.put(0, &[1.0], &[2.0]).unwrap();
        assert!(w.put(0, &[1.0], &[2.0]).is_err());
        assert!(w.put(5, &[1.0], &[2.0]).is_err());
        assert!(w.finalize("x", vec![]).is_err());
    }
}
