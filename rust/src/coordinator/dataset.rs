//! Dataset assembly: collect (input field, solution) pairs indexed by their
//! original stream id and export NumPy `.npy` arrays plus a JSON meta file —
//! directly loadable by the python FNO pipeline and by `no::data`.

use crate::util::json::Json;
use crate::util::npy::{self, NpyArray};
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// In-memory accumulation buffer for a dataset being generated out of order.
pub struct DatasetWriter {
    dir: PathBuf,
    count: usize,
    input_dim: usize,
    sol_dim: usize,
    inputs: Vec<f64>,
    solutions: Vec<f64>,
    filled: Vec<bool>,
    /// Grid side for reshaping on the python side (0 = unstructured).
    field_side: usize,
}

/// What was written where.
#[derive(Debug, Clone)]
pub struct DatasetSummary {
    pub dir: PathBuf,
    pub count: usize,
    pub input_dim: usize,
    pub sol_dim: usize,
}

impl DatasetWriter {
    pub fn new(dir: &Path, count: usize, input_dim: usize, sol_dim: usize, field_side: usize) -> DatasetWriter {
        DatasetWriter {
            dir: dir.to_path_buf(),
            count,
            input_dim,
            sol_dim,
            inputs: vec![0.0; count * input_dim],
            solutions: vec![0.0; count * sol_dim],
            filled: vec![false; count],
            field_side,
        }
    }

    /// Record sample `id` (original stream position).
    pub fn put(&mut self, id: usize, input: &[f64], solution: &[f64]) -> Result<()> {
        if id >= self.count {
            bail!("sample id {id} out of range {}", self.count);
        }
        if input.len() != self.input_dim || solution.len() != self.sol_dim {
            bail!(
                "dim mismatch for id {id}: input {} (want {}), sol {} (want {})",
                input.len(),
                self.input_dim,
                solution.len(),
                self.sol_dim
            );
        }
        // Last line of defense for distributed merges: the lease table
        // already rejects duplicate shard results, but a row can only ever
        // be written once regardless of who calls `put`.
        if self.filled[id] {
            bail!("sample id {id} written twice");
        }
        self.inputs[id * self.input_dim..(id + 1) * self.input_dim].copy_from_slice(input);
        self.solutions[id * self.sol_dim..(id + 1) * self.sol_dim].copy_from_slice(solution);
        self.filled[id] = true;
        Ok(())
    }

    pub fn complete(&self) -> bool {
        self.filled.iter().all(|&f| f)
    }

    /// Write `inputs.npy`, `solutions.npy` and `meta.json` — atomically.
    ///
    /// All three files land in a `<dir>.tmp` staging directory which is then
    /// renamed into place, so a crash (or a cancelled service job) mid-write
    /// can never leave a half-written dataset that [`load`] would misread:
    /// either the final directory exists with all three files, or it does
    /// not exist at all.
    pub fn finalize(self, family: &str, extra: Vec<(&str, Json)>) -> Result<DatasetSummary> {
        if !self.complete() {
            let missing = self.filled.iter().filter(|&&f| !f).count();
            bail!("dataset incomplete: {missing} of {} samples missing", self.count);
        }
        let staging = self.dir.with_extension("tmp");
        // A stale staging dir from a previous crashed run is dead weight.
        if staging.exists() {
            std::fs::remove_dir_all(&staging)?;
        }
        std::fs::create_dir_all(&staging)?;
        npy::write(
            &staging.join("inputs.npy"),
            &NpyArray::f64(vec![self.count, self.input_dim], self.inputs),
        )?;
        npy::write(
            &staging.join("solutions.npy"),
            &NpyArray::f64(vec![self.count, self.sol_dim], self.solutions),
        )?;
        let mut pairs = vec![
            ("family", Json::Str(family.to_string())),
            ("count", Json::Num(self.count as f64)),
            ("input_dim", Json::Num(self.input_dim as f64)),
            ("sol_dim", Json::Num(self.sol_dim as f64)),
            ("field_side", Json::Num(self.field_side as f64)),
        ];
        pairs.extend(extra);
        std::fs::write(staging.join("meta.json"), Json::obj(pairs).dump())?;
        // Publish: replace any previous dataset at the target path.
        if self.dir.exists() {
            std::fs::remove_dir_all(&self.dir)?;
        }
        if let Some(parent) = self.dir.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::rename(&staging, &self.dir)?;
        Ok(DatasetSummary {
            dir: self.dir,
            count: self.count,
            input_dim: self.input_dim,
            sol_dim: self.sol_dim,
        })
    }
}

/// Load a dataset written by [`DatasetWriter`] (used by the FNO trainer).
pub fn load(dir: &Path) -> Result<(NpyArray, NpyArray, Json)> {
    let inputs = npy::read(&dir.join("inputs.npy"))?;
    let solutions = npy::read(&dir.join("solutions.npy"))?;
    let meta = Json::parse(&std::fs::read_to_string(dir.join("meta.json"))?)?;
    Ok((inputs, solutions, meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Unique per-test scratch path: pid + global counter, so concurrently
    /// running tests (and stale files from killed runs) never collide.
    fn unique_dir(tag: &str) -> PathBuf {
        static COUNTER: AtomicUsize = AtomicUsize::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("skr_ds_{tag}_{}_{n}", std::process::id()))
    }

    #[test]
    fn roundtrip_out_of_order() {
        let dir = unique_dir("roundtrip");
        let mut w = DatasetWriter::new(&dir, 3, 2, 4, 2);
        w.put(2, &[5.0, 6.0], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        w.put(0, &[1.0, 2.0], &[0.0; 4]).unwrap();
        w.put(1, &[3.0, 4.0], &[9.0; 4]).unwrap();
        assert!(w.complete());
        let s = w.finalize("darcy", vec![]).unwrap();
        assert_eq!(s.count, 3);
        let (ins, sols, meta) = load(&dir).unwrap();
        assert_eq!(ins.shape, vec![3, 2]);
        assert_eq!(sols.shape, vec![3, 4]);
        assert_eq!(&ins.data[4..6], &[5.0, 6.0]);
        assert_eq!(meta.get("family").unwrap().as_str(), Some("darcy"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_double_write_and_incomplete() {
        let dir = unique_dir("rejects");
        let mut w = DatasetWriter::new(&dir, 2, 1, 1, 0);
        w.put(0, &[1.0], &[2.0]).unwrap();
        assert!(w.put(0, &[1.0], &[2.0]).is_err());
        assert!(w.put(5, &[1.0], &[2.0]).is_err());
        assert!(w.finalize("x", vec![]).is_err());
        // A failed finalize must not publish the dataset directory.
        assert!(!dir.exists());
    }

    #[test]
    fn finalize_is_atomic_no_staging_left_behind() {
        let dir = unique_dir("atomic");
        let staging = dir.with_extension("tmp");
        // A stale staging dir from a crashed run gets cleaned up.
        std::fs::create_dir_all(&staging).unwrap();
        std::fs::write(staging.join("inputs.npy"), b"garbage").unwrap();
        let mut w = DatasetWriter::new(&dir, 1, 1, 1, 0);
        w.put(0, &[1.0], &[2.0]).unwrap();
        w.finalize("darcy", vec![]).unwrap();
        assert!(dir.join("inputs.npy").exists());
        assert!(dir.join("solutions.npy").exists());
        assert!(dir.join("meta.json").exists());
        assert!(!staging.exists(), "staging dir must be renamed away");
        let (ins, _, _) = load(&dir).unwrap();
        assert_eq!(ins.data, vec![1.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn finalize_replaces_existing_dataset() {
        let dir = unique_dir("replace");
        let mut w = DatasetWriter::new(&dir, 1, 1, 1, 0);
        w.put(0, &[1.0], &[2.0]).unwrap();
        w.finalize("darcy", vec![]).unwrap();
        let mut w2 = DatasetWriter::new(&dir, 1, 1, 1, 0);
        w2.put(0, &[7.0], &[8.0]).unwrap();
        w2.finalize("darcy", vec![]).unwrap();
        let (ins, sols, _) = load(&dir).unwrap();
        assert_eq!(ins.data, vec![7.0]);
        assert_eq!(sols.data, vec![8.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
