//! Pipeline configuration — the single source of truth wired from the CLI
//! into every stage (generation, sorting, sharding, solving, export).

use super::sorter::SortStrategy;
use crate::pde::FamilyKind;
use crate::precond::PrecondKind;
use crate::solver::{Engine, SolverConfig};
use crate::util::args::Args;
use anyhow::Result;

/// Full configuration of one data-generation run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub family: FamilyKind,
    /// Target unknowns per system (grid chosen to match).
    pub unknowns: usize,
    /// Number of PDE instances to generate.
    pub count: usize,
    pub engine: Engine,
    pub precond: PrecondKind,
    pub sort: SortStrategy,
    pub solver: SolverConfig,
    /// Worker threads for the solve stage (the paper's MPI-rank analogue).
    pub threads: usize,
    /// Bounded-queue depth between the solve and export stages
    /// (backpressure: workers block when the writer falls behind).
    pub queue_depth: usize,
    pub seed: u64,
    /// Output directory for the dataset (None = do not export).
    pub out_dir: Option<std::path::PathBuf>,
    /// Record the δ-subspace instrumentation (slower; ablation only).
    pub instrument_delta: bool,
    /// Override the GRF smoothness exponent α for GRF-driven families
    /// (Darcy, Helmholtz). Larger α ⇒ smoother fields ⇒ lower effective
    /// parameter dimension ⇒ closer sorted neighbours at a given sample
    /// count (the ablation uses this at CI scale).
    pub grf_alpha: Option<f64>,
    /// Write a JSONL event trace (spans, per-system solves, per-cycle
    /// residuals, worker utilization) to this path (`--trace-out`).
    pub trace_out: Option<std::path::PathBuf>,
    /// Live progress line on stderr during the solve stage (`--progress`).
    pub progress: bool,
    /// Treat any MaxIters/Breakdown system as a run failure (`--strict`).
    pub strict: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            family: FamilyKind::Darcy,
            unknowns: 2500,
            count: 64,
            engine: Engine::SkrRecycle,
            precond: PrecondKind::None,
            sort: SortStrategy::Greedy,
            solver: SolverConfig::default(),
            threads: 1,
            queue_depth: 64,
            seed: 0,
            out_dir: None,
            instrument_delta: false,
            grf_alpha: None,
            trace_out: None,
            progress: false,
            strict: false,
        }
    }
}

impl PipelineConfig {
    /// Build from parsed CLI arguments (shared by `skr generate` and benches).
    pub fn from_args(args: &Args) -> Result<PipelineConfig> {
        let mut cfg = PipelineConfig {
            family: FamilyKind::parse(&args.str_or("family", "darcy"))?,
            unknowns: args.num_or("n", 2500usize),
            count: args.num_or("count", 64usize),
            engine: Engine::parse(&args.str_or("engine", "skr"))?,
            precond: PrecondKind::parse(&args.str_or("precond", "none"))?,
            sort: SortStrategy::parse(&args.str_or("sort", "greedy"))?,
            threads: args.num_or("threads", 1usize).max(1),
            queue_depth: args.num_or("queue-depth", 64usize).max(1),
            seed: args.num_or("seed", 0u64),
            out_dir: args.get("out").map(std::path::PathBuf::from),
            instrument_delta: args.flag("delta"),
            grf_alpha: args.get("grf-alpha").and_then(|v| v.parse().ok()),
            trace_out: args.get("trace-out").map(std::path::PathBuf::from),
            progress: args.flag("progress"),
            strict: args.flag("strict"),
            solver: SolverConfig::default(),
        };
        cfg.solver.tol = args.num_or("tol", 1e-8f64);
        cfg.solver.m = args.num_or("m", 30usize);
        cfg.solver.k = args.num_or("k", 10usize);
        cfg.solver.max_iters = args.num_or("max-iters", 10_000usize);
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_args_parses_everything() {
        let args = Args::parse(
            "generate --family helmholtz --n 400 --count 10 --engine gmres \
             --precond sor --sort none --threads 4 --tol 1e-5 --m 40 --k 12 --seed 9 \
             --trace-out /tmp/t.jsonl --progress --strict"
                .split_whitespace()
                .map(|s| s.to_string()),
        );
        let cfg = PipelineConfig::from_args(&args).unwrap();
        assert_eq!(cfg.family, FamilyKind::Helmholtz);
        assert_eq!(cfg.unknowns, 400);
        assert_eq!(cfg.count, 10);
        assert_eq!(cfg.engine, Engine::Gmres);
        assert_eq!(cfg.precond, PrecondKind::Sor);
        assert_eq!(cfg.sort, SortStrategy::None);
        assert_eq!(cfg.threads, 4);
        assert!((cfg.solver.tol - 1e-5).abs() < 1e-18);
        assert_eq!(cfg.solver.m, 40);
        assert_eq!(cfg.solver.k, 12);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.trace_out, Some(std::path::PathBuf::from("/tmp/t.jsonl")));
        assert!(cfg.progress);
        assert!(cfg.strict);
    }

    #[test]
    fn observability_flags_default_off() {
        let args = Args::parse(["generate".to_string()].into_iter());
        let cfg = PipelineConfig::from_args(&args).unwrap();
        assert!(cfg.trace_out.is_none());
        assert!(!cfg.progress);
        assert!(!cfg.strict);
    }
}
