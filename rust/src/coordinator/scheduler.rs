//! Batch scheduler: shard the *sorted* solve order into contiguous
//! per-worker batches (the paper's Appendix E.2.2 parallel strategy — each
//! MPI rank/thread receives a contiguous, internally-similar run of systems
//! and recycles within it).

/// Split `order` into `workers` contiguous batches of near-equal size.
pub fn shard(order: &[usize], workers: usize) -> Vec<Vec<usize>> {
    let workers = workers.clamp(1, order.len().max(1));
    let n = order.len();
    let base = n / workers;
    let rem = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        out.push(order[start..start + len].to_vec());
        start += len;
    }
    out
}

/// Interleaved sharding (round-robin) — the *wrong* strategy for recycling
/// (it destroys consecutive similarity); kept as an ablation arm.
pub fn shard_interleaved(order: &[usize], workers: usize) -> Vec<Vec<usize>> {
    let workers = workers.clamp(1, order.len().max(1));
    let mut out = vec![Vec::new(); workers];
    for (i, &id) in order.iter().enumerate() {
        out[i % workers].push(id);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_covers_all_once() {
        let order: Vec<usize> = (0..17).rev().collect();
        let shards = shard(&order, 4);
        assert_eq!(shards.len(), 4);
        let flat: Vec<usize> = shards.iter().flatten().copied().collect();
        assert_eq!(flat, order);
        // Sizes are near equal.
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes, vec![5, 4, 4, 4]);
    }

    #[test]
    fn more_workers_than_items_clamps() {
        let order = vec![1, 2];
        let shards = shard(&order, 8);
        assert_eq!(shards.len(), 2);
    }

    #[test]
    fn interleaved_distributes_round_robin() {
        let order: Vec<usize> = (0..6).collect();
        let shards = shard_interleaved(&order, 2);
        assert_eq!(shards[0], vec![0, 2, 4]);
        assert_eq!(shards[1], vec![1, 3, 5]);
    }

    #[test]
    fn empty_order_yields_one_empty_shard() {
        for workers in [1, 4, 100] {
            let shards = shard(&[], workers);
            assert_eq!(shards.len(), 1, "shard(&[], {workers})");
            assert!(shards[0].is_empty());
            let shards = shard_interleaved(&[], workers);
            assert_eq!(shards.len(), 1, "shard_interleaved(&[], {workers})");
            assert!(shards[0].is_empty());
        }
        // workers = 0 clamps up to 1 rather than dividing by zero.
        assert_eq!(shard(&[7, 8], 0), vec![vec![7, 8]]);
        assert_eq!(shard_interleaved(&[7, 8], 0), vec![vec![7, 8]]);
    }

    /// Property: sharding any order under any worker count is a *partition* —
    /// every id appears in exactly one shard, and no shard is introduced or
    /// dropped beyond the clamped worker count.
    #[test]
    fn sharding_is_a_partition() {
        use crate::util::propcheck::{check_msg, Config};
        let verify = |order: &[usize], workers: usize, shards: &[Vec<usize>]| -> Result<(), String> {
            let expect = workers.clamp(1, order.len().max(1));
            if shards.len() != expect {
                return Err(format!("{} shards, expected {expect}", shards.len()));
            }
            let mut flat: Vec<usize> = shards.iter().flatten().copied().collect();
            flat.sort_unstable();
            let mut want = order.to_vec();
            want.sort_unstable();
            if flat != want {
                return Err(format!("not a partition: {flat:?} vs {want:?}"));
            }
            Ok(())
        };
        check_msg(
            "shard-partition",
            Config { cases: 128, seed: 0x5AAD },
            |rng| {
                let n = (rng.uniform() * 40.0) as usize;
                let workers = (rng.uniform() * 12.0) as usize;
                // A permutation of 0..n (what sort_order produces).
                let mut order: Vec<usize> = (0..n).collect();
                for i in (1..n).rev() {
                    let j = (rng.uniform() * (i + 1) as f64) as usize % (i + 1);
                    order.swap(i, j);
                }
                (order, workers)
            },
            |(order, workers)| {
                verify(order, *workers, &shard(order, *workers))?;
                verify(order, *workers, &shard_interleaved(order, *workers))?;
                // Contiguous sharding additionally preserves the solve order.
                let flat: Vec<usize> =
                    shard(order, *workers).iter().flatten().copied().collect();
                if flat != *order {
                    return Err(format!("contiguous shard reordered: {flat:?}"));
                }
                Ok(())
            },
        );
    }
}
