//! Batch scheduler: shard the *sorted* solve order into contiguous
//! per-worker batches (the paper's Appendix E.2.2 parallel strategy — each
//! MPI rank/thread receives a contiguous, internally-similar run of systems
//! and recycles within it).

/// Split `order` into `workers` contiguous batches of near-equal size.
pub fn shard(order: &[usize], workers: usize) -> Vec<Vec<usize>> {
    let workers = workers.clamp(1, order.len().max(1));
    let n = order.len();
    let base = n / workers;
    let rem = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        out.push(order[start..start + len].to_vec());
        start += len;
    }
    out
}

/// Interleaved sharding (round-robin) — the *wrong* strategy for recycling
/// (it destroys consecutive similarity); kept as an ablation arm.
pub fn shard_interleaved(order: &[usize], workers: usize) -> Vec<Vec<usize>> {
    let workers = workers.clamp(1, order.len().max(1));
    let mut out = vec![Vec::new(); workers];
    for (i, &id) in order.iter().enumerate() {
        out[i % workers].push(id);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_covers_all_once() {
        let order: Vec<usize> = (0..17).rev().collect();
        let shards = shard(&order, 4);
        assert_eq!(shards.len(), 4);
        let flat: Vec<usize> = shards.iter().flatten().copied().collect();
        assert_eq!(flat, order);
        // Sizes are near equal.
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes, vec![5, 4, 4, 4]);
    }

    #[test]
    fn more_workers_than_items_clamps() {
        let order = vec![1, 2];
        let shards = shard(&order, 8);
        assert_eq!(shards.len(), 2);
    }

    #[test]
    fn interleaved_distributes_round_robin() {
        let order: Vec<usize> = (0..6).collect();
        let shards = shard_interleaved(&order, 2);
        assert_eq!(shards[0], vec![0, 2, 4]);
        assert_eq!(shards[1], vec![1, 3, 5]);
    }
}
