//! δ-subspace instrumentation (paper §5.1/§6.3): the one-sided distance
//! δ(Q, C) = ‖(I − Π_C) Π_Q‖₂ between the recycle space C carried from
//! system i and the space Q harvested from system i+1 — small δ predicts
//! fast GCRO-DR convergence, and the ablation (Table 2) shows sorting
//! lowers it.

use crate::la::svd::{subspace_sin_max, subspace_sin_mean};
use crate::la::Mat;

/// Both flavours of the subspace distance between consecutive recycle
/// spaces: `max` is the paper's spectral δ = ‖(I−Π_C)Π_Q‖₂ (the largest
/// principal-angle sine, which saturates at 1 for k ≳ 5 in practice) and
/// `mean` averages all k principal-angle sines — the discriminative variant
/// the sort ablation reports alongside it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Delta {
    pub max: f64,
    pub mean: f64,
}

/// Orthonormalize columns and compute δ between consecutive recycle spaces.
/// Inputs are column sets (each a length-n vector); returns None if either
/// set is empty or degenerate.
pub fn delta_between(c_prev: &[Vec<f64>], q_next: &[Vec<f64>]) -> Option<Delta> {
    let ortho = |cols: &[Vec<f64>]| -> Option<Mat> {
        if cols.is_empty() {
            return None;
        }
        let n = cols[0].len();
        let mut m = Mat::zeros(n, cols.len());
        for (j, c) in cols.iter().enumerate() {
            m.set_col(j, c);
        }
        let (q, r) = m.qr_thin();
        // Degenerate if any diagonal collapses.
        for i in 0..cols.len() {
            if r[(i, i)].abs() < 1e-12 {
                return None;
            }
        }
        Some(q)
    };
    let c = ortho(c_prev)?;
    let q = ortho(q_next)?;
    Some(Delta { max: subspace_sin_max(&c, &q), mean: subspace_sin_mean(&c, &q) })
}

/// Running means of δ values observed along a sequence (both flavours).
#[derive(Debug, Default, Clone)]
pub struct DeltaTracker {
    sum_max: f64,
    sum_mean: f64,
    count: usize,
    values: Vec<Delta>,
}

impl DeltaTracker {
    pub fn record(&mut self, delta: Delta) {
        self.sum_max += delta.max;
        self.sum_mean += delta.mean;
        self.count += 1;
        self.values.push(delta);
    }

    /// Sequence mean of the spectral δ (largest principal-angle sine).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_max / self.count as f64
        }
    }

    /// Sequence mean of the mean-principal-angle δ (discriminative variant).
    pub fn mean_of_means(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_mean / self.count as f64
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn values(&self) -> &[Delta] {
        &self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn identical_spaces_have_zero_delta() {
        let mut rng = Rng::new(1);
        let cols: Vec<Vec<f64>> = (0..3).map(|_| rng.normals(20)).collect();
        let d = delta_between(&cols, &cols).unwrap();
        assert!(d.max < 1e-7, "{d:?}");
        assert!(d.mean < 1e-7, "{d:?}");
    }

    #[test]
    fn disjoint_spaces_have_delta_one() {
        let mut a = vec![vec![0.0; 8]; 2];
        a[0][0] = 1.0;
        a[1][1] = 1.0;
        let mut b = vec![vec![0.0; 8]; 2];
        b[0][4] = 1.0;
        b[1][5] = 1.0;
        let d = delta_between(&a, &b).unwrap();
        assert!((d.max - 1.0).abs() < 1e-12);
        assert!((d.mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_degenerate_sets() {
        let z = vec![vec![0.0; 4]; 2];
        assert!(delta_between(&z, &z).is_none());
        assert!(delta_between(&[], &[]).is_none());
    }

    #[test]
    fn tracker_means() {
        let mut t = DeltaTracker::default();
        t.record(Delta { max: 0.5, mean: 0.25 });
        t.record(Delta { max: 1.0, mean: 0.75 });
        assert!((t.mean() - 0.75).abs() < 1e-15);
        assert!((t.mean_of_means() - 0.5).abs() < 1e-15);
        assert_eq!(t.count(), 2);
    }
}
