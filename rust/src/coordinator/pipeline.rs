//! The end-to-end SKR pipeline (paper Fig. 1/2):
//!
//! 1. **Parameter pass** — draw each instance's parameter matrix from its
//!    deterministic RNG stream (cheap; no matrices assembled).
//! 2. **Sort** — serialize by parameter similarity (Algorithm 1 / variants).
//! 3. **Shard** — contiguous batches per worker thread (Appendix E.2.2).
//! 4. **Solve** — each worker regenerates its systems on demand (bounded
//!    memory), solves sequentially with GCRO-DR recycling (or GMRES), and
//!    streams `(id, input, solution)` to the writer through a bounded
//!    channel — backpressure throttles the solvers if the writer lags.
//! 5. **Assemble** — `.npy` dataset + metrics.
//!
//! Observability: every stage is timed as a [`Recorder`] span on one shared
//! timeline (`gen`, `sort`, `shard`, `solve`, `solve/w{i}`,
//! `solve/w{i}/sys{id}`); when `cfg.trace_out` is set the run additionally
//! streams a JSONL event trace ([`TraceSink`]) with per-cycle residuals from
//! a [`RecordingObserver`] threaded into the solvers; with tracing off a
//! [`NoopObserver`] rides the same workspace entry points — bit-identical
//! numerics either way.
//!
//! Each worker owns the per-shard reusable state: one solver [`Workspace`],
//! one cached `SymbolicPrecond` keyed on the matrix `Sparsity`, and one
//! [`Recycler`]. The reuse tallies surface in [`RunMetrics`] and the trace's
//! `run` event.

use super::config::PipelineConfig;
use super::control::{Cancelled, RunControl};
use super::dataset::{DatasetSummary, DatasetWriter};
use super::delta::{delta_between, DeltaTracker};
use super::metrics::RunMetrics;
use super::scheduler::shard;
use super::sorter::sort_order;
use crate::la::Sparsity;
use crate::obs::{NoopObserver, Progress, Recorder, RecordingObserver, SpanRecord, TraceSink};
use crate::pde::ProblemFamily;
use crate::precond::SymbolicPrecond;
use crate::solver::{
    gcrodr_ws, gmres_ws, Engine, Recycler, SolveCounters, SolveStats, StopReason, Workspace,
};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::timer::Timer;
use anyhow::{Context, Result};
use std::sync::Arc;
use std::sync::mpsc::sync_channel;

/// Per-worker utilization rollup for one pipeline run.
#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub worker: usize,
    pub systems: usize,
    /// Seconds spent inside solver calls.
    pub busy_seconds: f64,
    /// Worker thread lifetime in seconds.
    pub wall_seconds: f64,
    /// Seconds blocked in the bounded writer channel (`tx.send`).
    pub backpressure_seconds: f64,
}

impl WorkerReport {
    pub fn utilization(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.busy_seconds / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Outcome of a pipeline run.
pub struct PipelineResult {
    pub metrics: RunMetrics,
    /// (original id, stats) in solve order, concatenated across workers.
    pub per_system: Vec<(usize, SolveStats)>,
    /// δ between consecutive recycle spaces (when instrumented).
    pub delta: DeltaTracker,
    pub dataset: Option<DatasetSummary>,
    /// The solve order that was used.
    pub order: Vec<usize>,
    /// Stage/worker/system spans on one shared timeline.
    pub spans: Vec<SpanRecord>,
    pub workers: Vec<WorkerReport>,
}

/// The deterministic front half of a run (stages 1–3): parameter vectors,
/// similarity-sorted solve order, and contiguous shards. Shared between
/// [`Pipeline::run_with`] and `skr coordinate` — both derive the *same*
/// plan from the same config, which is what makes a distributed run
/// bit-identical to a single-node one.
#[derive(Debug, Clone)]
pub struct RunPlan {
    /// Per-instance parameter vectors, indexed by original id.
    pub params: Vec<Vec<f64>>,
    /// Solve order over original ids (similarity-serialized).
    pub order: Vec<usize>,
    /// Contiguous slices of `order`, one per worker/shard.
    pub shards: Vec<Vec<usize>>,
    pub gen_seconds: f64,
    pub sort_seconds: f64,
    pub shard_seconds: f64,
}

/// The pipeline entry point.
pub struct Pipeline {
    cfg: PipelineConfig,
    family: Box<dyn ProblemFamily>,
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Pipeline {
        let family = cfg.family.build_with(cfg.unknowns, cfg.grf_alpha);
        Pipeline { cfg, family }
    }

    /// Run the pipeline over a caller-constructed problem family (custom
    /// permeability maps, meshes, …); `cfg.family`/`cfg.unknowns` are then
    /// informational only.
    pub fn with_family(cfg: PipelineConfig, family: Box<dyn ProblemFamily>) -> Pipeline {
        Pipeline { cfg, family }
    }

    /// Access the problem family (for examples that need grid metadata).
    pub fn family(&self) -> &dyn ProblemFamily {
        self.family.as_ref()
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Run the full pipeline.
    pub fn run(&self) -> Result<PipelineResult> {
        self.run_with(&RunControl::new())
    }

    /// Stages 1–3 (parameter pass → sort → shard) as a standalone plan over
    /// `shards` contiguous batches. Pure function of the config and `shards`:
    /// [`Pipeline::run_with`] computes exactly this with
    /// `shards == cfg.threads`, and `skr coordinate` hands the same batches
    /// to remote workers.
    pub fn plan(&self, shards: usize) -> Result<RunPlan> {
        self.plan_recorded(shards, &Recorder::new())
    }

    /// [`Pipeline::plan`], with the `gen`/`sort`/`shard` stage spans landed
    /// on a caller-owned timeline (`skr coordinate` shares one recorder
    /// between the plan and the per-shard merge spans).
    pub fn plan_recorded(&self, shard_count: usize, recorder: &Recorder) -> Result<RunPlan> {
        let cfg = &self.cfg;
        let master = Rng::new(cfg.seed);

        // 1. Parameter pass.
        let gen_start = recorder.now();
        let params: Vec<Vec<f64>> = (0..cfg.count)
            .map(|i| self.family.sample_params(i, &mut master.split(i as u64)))
            .collect::<Result<_>>()?;
        let gen_seconds = recorder.now() - gen_start;
        recorder.record("gen", None, gen_start, gen_seconds);

        // 2. Sort.
        let sort_start = recorder.now();
        let order = sort_order(&params, cfg.sort, cfg.seed ^ 0x5EED);
        let sort_seconds = recorder.now() - sort_start;
        recorder.record("sort", None, sort_start, sort_seconds);

        // 3. Shard.
        let shard_start = recorder.now();
        let shards = shard(&order, shard_count);
        let shard_seconds = recorder.now() - shard_start;
        recorder.record("shard", None, shard_start, shard_seconds);

        Ok(RunPlan { params, order, shards, gen_seconds, sort_seconds, shard_seconds })
    }

    /// Run the full pipeline under external supervision.
    ///
    /// `ctl` carries a cooperative cancellation token — checked between
    /// system solves, so a cancelled run stops within one solve, skips
    /// dataset finalization, and returns `Err` downcastable to
    /// [`Cancelled`] — and live progress counters (systems done/total plus
    /// the reuse tallies) that another thread may read mid-run.
    pub fn run_with(&self, ctl: &RunControl) -> Result<PipelineResult> {
        let wall = Timer::start();
        let cfg = &self.cfg;
        ctl.set_total(cfg.count);
        let master = Rng::new(cfg.seed);
        let recorder = Recorder::new();
        let sink = match &cfg.trace_out {
            Some(path) => Some(TraceSink::create(path)?),
            None => None,
        };
        if let Some(sink) = &sink {
            sink.emit(&Json::obj(vec![
                ("ev", Json::Str("meta".into())),
                ("family", Json::Str(self.family.name().into())),
                ("engine", Json::Str(cfg.engine.label().into())),
                ("count", Json::Num(cfg.count as f64)),
                ("n", Json::Num(cfg.unknowns as f64)),
                ("threads", Json::Num(cfg.threads as f64)),
                ("tol", Json::Num(cfg.solver.tol)),
                ("seed", Json::Num(cfg.seed as f64)),
            ]));
        }

        // 1–3. Parameter pass → sort → shard (the shared deterministic plan).
        let RunPlan { params, order, shards, gen_seconds, sort_seconds, .. } =
            self.plan_recorded(cfg.threads, &recorder)?;

        // 4. Solve (+ stream to writer).
        let input_dim = params.first().map_or(0, |p| p.len());
        let sol_dim = self.family.num_unknowns();
        let mut writer = cfg.out_dir.as_ref().map(|dir| {
            DatasetWriter::new(dir, cfg.count, input_dim, sol_dim, self.family.field_side())
        });

        let (tx, rx) = sync_channel::<(usize, Vec<f64>, Vec<f64>)>(cfg.queue_depth);
        let export = writer.is_some();
        let family = self.family.as_ref();
        let progress = Progress::new(cfg.count, cfg.progress);
        let sink_ref = sink.as_ref();

        let mut worker_outputs: Vec<WorkerOutput> = Vec::new();
        let solve_start = recorder.now();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for (w, batch) in shards.iter().enumerate() {
                let tx = tx.clone();
                let master = master.clone();
                let recorder = &recorder;
                let progress = &progress;
                handles.push(scope.spawn(move || -> Result<WorkerOutput> {
                    solve_batch(
                        family,
                        cfg,
                        w,
                        batch,
                        &master,
                        export.then_some(tx),
                        sink_ref,
                        progress,
                        recorder,
                        ctl,
                    )
                }));
            }
            drop(tx);
            // Writer loop on this thread (bounded channel = backpressure).
            if let Some(w) = writer.as_mut() {
                while let Ok((id, input, solution)) = rx.recv() {
                    w.put(id, &input, &solution)?;
                }
            } else {
                drop(rx);
            }
            for h in handles {
                worker_outputs.push(h.join().expect("worker panicked")?);
            }
            Ok(())
        })?;
        recorder.record("solve", None, solve_start, recorder.now() - solve_start);
        progress.finish();

        // Cancelled: drop all partial work on the floor — in particular the
        // dataset is never finalized, so no (partial) directory appears.
        if ctl.is_cancelled() {
            return Err(anyhow::Error::new(Cancelled));
        }

        // 5. Assemble.
        let mut metrics = RunMetrics::default();
        let mut per_system = Vec::with_capacity(cfg.count);
        let mut delta = DeltaTracker::default();
        let mut workers = Vec::with_capacity(worker_outputs.len());
        for out in worker_outputs {
            for (id, s) in out.stats {
                metrics.absorb(&s);
                per_system.push((id, s));
            }
            for d in out.deltas {
                metrics.record_delta(d.max);
                delta.record(d);
            }
            metrics.backpressure_seconds += out.backpressure_seconds;
            metrics.sparsity_reuse += out.sparsity_reuse;
            metrics.symbolic_reuse += out.symbolic_reuse;
            metrics.workspace_reuse += out.workspace_reuse;
            metrics.counters.merge(&out.counters);
            workers.push(WorkerReport {
                worker: out.worker,
                systems: out.systems,
                busy_seconds: out.busy_seconds,
                wall_seconds: out.wall_seconds,
                backpressure_seconds: out.backpressure_seconds,
            });
        }
        workers.sort_by_key(|w| w.worker);
        metrics.gen_seconds = gen_seconds;
        metrics.sort_seconds = sort_seconds;
        metrics.wall_seconds = wall.secs();
        let spans = recorder.spans();

        if let Some(sink) = &sink {
            for w in &workers {
                sink.emit(&TraceSink::worker_event(
                    w.worker,
                    w.systems,
                    w.busy_seconds,
                    w.wall_seconds,
                    w.backpressure_seconds,
                ));
            }
            for sp in &spans {
                sink.emit(&TraceSink::span_event(sp));
            }
            sink.emit(&Json::obj(vec![
                ("ev", Json::Str("run".into())),
                ("systems", Json::Num(metrics.systems as f64)),
                ("total_iters", Json::Num(metrics.total_iters as f64)),
                ("solve_seconds", Json::Num(metrics.solve_seconds)),
                ("max_iter_hits", Json::Num(metrics.max_iter_hits as f64)),
                ("breakdowns", Json::Num(metrics.breakdowns as f64)),
                ("gen_seconds", Json::Num(metrics.gen_seconds)),
                ("sort_seconds", Json::Num(metrics.sort_seconds)),
                ("wall_seconds", Json::Num(metrics.wall_seconds)),
                ("rel_residual_worst", Json::Num(metrics.rel_residual_worst)),
                ("backpressure_seconds", Json::Num(metrics.backpressure_seconds)),
                ("sparsity_reuse", Json::Num(metrics.sparsity_reuse as f64)),
                ("symbolic_reuse", Json::Num(metrics.symbolic_reuse as f64)),
                ("workspace_reuse", Json::Num(metrics.workspace_reuse as f64)),
                ("matvecs", Json::Num(metrics.counters.matvecs as f64)),
                ("precond_applies", Json::Num(metrics.counters.precond_applies as f64)),
                ("ortho_flops", Json::Num(metrics.counters.ortho_flops as f64)),
                ("recycle_reseeds", Json::Num(metrics.counters.recycle_reseeds as f64)),
                ("recycle_carries", Json::Num(metrics.counters.recycle_carries as f64)),
                ("harvests", Json::Num(metrics.counters.harvests as f64)),
            ]));
            sink.flush();
        }

        let dataset = match writer {
            Some(w) => Some(
                w.finalize(
                    self.family.name(),
                    vec![
                        ("engine", Json::Str(cfg.engine.label().into())),
                        ("tol", Json::Num(cfg.solver.tol)),
                        ("seed", Json::Num(cfg.seed as f64)),
                    ],
                )
                .context("finalizing dataset")?,
            ),
            None => None,
        };

        Ok(PipelineResult { metrics, per_system, delta, dataset, order, spans, workers })
    }
}

struct WorkerOutput {
    worker: usize,
    systems: usize,
    stats: Vec<(usize, SolveStats)>,
    deltas: Vec<super::delta::Delta>,
    busy_seconds: f64,
    wall_seconds: f64,
    backpressure_seconds: f64,
    sparsity_reuse: usize,
    symbolic_reuse: usize,
    workspace_reuse: usize,
    counters: SolveCounters,
}

/// Solve one contiguous batch sequentially, recycling across its systems.
///
/// When `sink` is set, solves run with a [`RecordingObserver`] and the
/// buffered events stream out as JSONL; otherwise a [`NoopObserver`] rides
/// along (identical numerics, zero tracing overhead). Either way the solves
/// share one [`Workspace`] and one cached symbolic preconditioner phase —
/// after the shard's first system, steady state performs no Krylov-buffer
/// allocation and no symbolic factorization.
#[allow(clippy::too_many_arguments)]
fn solve_batch(
    family: &dyn ProblemFamily,
    cfg: &PipelineConfig,
    worker: usize,
    batch: &[usize],
    master: &Rng,
    tx: Option<std::sync::mpsc::SyncSender<(usize, Vec<f64>, Vec<f64>)>>,
    sink: Option<&TraceSink>,
    progress: &Progress,
    recorder: &Recorder,
    ctl: &RunControl,
) -> Result<WorkerOutput> {
    let worker_start = recorder.now();
    let mut rec = Recycler::new();
    let mut ws = Workspace::new();
    let mut symbolic: Option<SymbolicPrecond> = None;
    let mut prev_sparsity: Option<Arc<Sparsity>> = None;
    let mut sparsity_reuse = 0usize;
    let mut symbolic_reuse = 0usize;
    let mut stats = Vec::with_capacity(batch.len());
    let mut deltas = Vec::new();
    let mut prev_space: Option<Vec<Vec<f64>>> = None;
    let mut busy_seconds = 0.0;
    let mut backpressure_seconds = 0.0;
    for &id in batch {
        // Cooperative cancellation point: a cancelled run stops before the
        // next system, i.e. within one solve of the cancel request.
        if ctl.is_cancelled() {
            break;
        }
        let ws_reuse_before = ws.reuse_count();
        let sys = family.sample(id, &mut master.split(id as u64))?;
        let sparsity_reused =
            prev_sparsity.as_ref().is_some_and(|sp| Arc::ptr_eq(sp, sys.a.sparsity()));
        if sparsity_reused {
            sparsity_reuse += 1;
        } else {
            prev_sparsity = Some(sys.a.sparsity().clone());
        }
        let mut symbolic_reused = false;
        let sym = match symbolic.take() {
            Some(s) if s.matches(&sys.a) => {
                symbolic_reuse += 1;
                symbolic_reused = true;
                s
            }
            _ => cfg.precond.symbolic(sys.a.sparsity())?,
        };
        let p = sym.refactor(&sys.a)?;
        symbolic = Some(sym);
        let mut x = vec![0.0; sys.b.len()];
        let sys_start = recorder.now();
        let s = if let Some(sink) = sink {
            let mut obs = RecordingObserver::new();
            let s = match cfg.engine {
                Engine::Gmres => {
                    gmres_ws(&sys.a, &sys.b, &mut x, p.as_ref(), &cfg.solver, &mut obs, &mut ws)
                }
                Engine::SkrRecycle => gcrodr_ws(
                    &sys.a,
                    &sys.b,
                    &mut x,
                    p.as_ref(),
                    &cfg.solver,
                    &mut rec,
                    &mut obs,
                    &mut ws,
                ),
            };
            sink.emit_all(&TraceSink::solve_events(
                id,
                worker,
                cfg.engine.label(),
                sys.b.len(),
                &s,
                &obs.events,
            ));
            s
        } else {
            match cfg.engine {
                Engine::Gmres => gmres_ws(
                    &sys.a,
                    &sys.b,
                    &mut x,
                    p.as_ref(),
                    &cfg.solver,
                    &mut NoopObserver,
                    &mut ws,
                ),
                Engine::SkrRecycle => gcrodr_ws(
                    &sys.a,
                    &sys.b,
                    &mut x,
                    p.as_ref(),
                    &cfg.solver,
                    &mut rec,
                    &mut NoopObserver,
                    &mut ws,
                ),
            }
        };
        recorder.record(
            &format!("solve/w{worker}/sys{id}"),
            Some(worker),
            sys_start,
            recorder.now() - sys_start,
        );
        busy_seconds += s.seconds;
        if cfg.instrument_delta {
            if let (Some(prev), Some(cur)) = (&prev_space, &rec.ytilde) {
                if let Some(d) = delta_between(prev, cur) {
                    deltas.push(d);
                }
            }
            prev_space = rec.ytilde.clone();
        }
        if let Some(tx) = &tx {
            // Blocking send — backpressure when the writer is saturated.
            let send_start = recorder.now();
            tx.send((id, family.input_field(&sys), x))
                .map_err(|_| anyhow::anyhow!("writer hung up"))?;
            backpressure_seconds += recorder.now() - send_start;
        }
        progress.tick(s.iters, matches!(s.stop, StopReason::MaxIters));
        ctl.note_system(sparsity_reused, symbolic_reused, ws.reuse_count() > ws_reuse_before);
        stats.push((id, s));
    }
    let wall_seconds = recorder.now() - worker_start;
    recorder.record(&format!("solve/w{worker}"), Some(worker), worker_start, wall_seconds);
    Ok(WorkerOutput {
        worker,
        systems: batch.len(),
        stats,
        deltas,
        busy_seconds,
        wall_seconds,
        backpressure_seconds,
        sparsity_reuse,
        symbolic_reuse,
        workspace_reuse: ws.reuse_count(),
        counters: *ws.counters(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sorter::SortStrategy;
    use crate::obs::TraceReport;
    use crate::pde::FamilyKind;
    use crate::precond::PrecondKind;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Unique per-test scratch path: pid + global counter, so concurrently
    /// running tests (and stale files from killed runs) never collide.
    fn unique_path(tag: &str) -> PathBuf {
        static COUNTER: AtomicUsize = AtomicUsize::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("skr_{tag}_{}_{n}", std::process::id()))
    }

    fn small_cfg() -> PipelineConfig {
        PipelineConfig {
            family: FamilyKind::Darcy,
            unknowns: 100,
            count: 12,
            engine: Engine::SkrRecycle,
            precond: PrecondKind::Jacobi,
            sort: SortStrategy::Greedy,
            threads: 2,
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn runs_end_to_end_and_converges() {
        let p = Pipeline::new(small_cfg());
        let r = p.run().unwrap();
        assert_eq!(r.metrics.systems, 12);
        assert_eq!(r.per_system.len(), 12);
        assert_eq!(r.metrics.max_iter_hits, 0);
        assert!(r.metrics.mean_iters() > 0.0);
        // Stage + worker + per-system spans always land on the timeline.
        let names: Vec<&str> = r.spans.iter().map(|s| s.name.as_str()).collect();
        for stage in ["gen", "sort", "shard", "solve"] {
            assert!(names.contains(&stage), "missing {stage} span in {names:?}");
        }
        assert_eq!(r.spans.iter().filter(|s| s.depth() == 2).count(), 12);
        assert_eq!(r.workers.len(), 2);
        assert_eq!(r.workers.iter().map(|w| w.systems).sum::<usize>(), 12);
        for w in &r.workers {
            assert!(w.utilization() > 0.0 && w.utilization() <= 1.0 + 1e-9, "{w:?}");
        }
        // Darcy stamps every sample onto one shared pattern, so each worker
        // reuses structure, symbolic phase and workspace for every system
        // after its shard's first: 12 systems − 2 workers = 10 each.
        assert_eq!(r.metrics.sparsity_reuse, 10);
        assert_eq!(r.metrics.symbolic_reuse, 10);
        assert_eq!(r.metrics.workspace_reuse, 10);
    }

    #[test]
    fn plan_matches_the_run_it_feeds() {
        let p = Pipeline::new(small_cfg());
        let plan = p.plan(2).unwrap();
        assert_eq!(plan.params.len(), 12);
        assert_eq!(plan.shards.len(), 2);
        let flat: Vec<usize> = plan.shards.iter().flatten().copied().collect();
        assert_eq!(flat, plan.order, "shards must be contiguous slices of the order");
        let r = p.run().unwrap();
        assert_eq!(r.order, plan.order, "run must solve the planned order");
        // Planning is a pure function of (config, shard count).
        let again = p.plan(2).unwrap();
        assert_eq!(again.order, plan.order);
        assert_eq!(again.params, plan.params);
    }

    #[test]
    fn exports_complete_dataset() {
        let dir = unique_path("pipe_ds");
        let mut cfg = small_cfg();
        cfg.out_dir = Some(dir.clone());
        let r = Pipeline::new(cfg).run().unwrap();
        let ds = r.dataset.unwrap();
        assert_eq!(ds.count, 12);
        let (ins, sols, _) = crate::coordinator::dataset::load(&dir).unwrap();
        assert_eq!(ins.shape[0], 12);
        assert_eq!(sols.shape, vec![12, 100]);
        // Solutions should be nontrivial.
        assert!(sols.data.iter().any(|&v| v.abs() > 1e-12));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn skr_beats_gmres_on_iterations() {
        // Needs a problem hard enough that GMRES restarts several times —
        // recycling overhead (k seed matvecs + harvest cycle) only amortizes
        // then (the paper's sizes start at n = 2500).
        let mut cfg = small_cfg();
        cfg.unknowns = 625;
        cfg.solver.tol = 1e-9;
        cfg.count = 10;
        cfg.threads = 1;
        let skr = Pipeline::new(cfg.clone()).run().unwrap();
        cfg.engine = Engine::Gmres;
        let gm = Pipeline::new(cfg).run().unwrap();
        assert!(
            skr.metrics.mean_iters() < gm.metrics.mean_iters(),
            "SKR {} vs GMRES {}",
            skr.metrics.mean_iters(),
            gm.metrics.mean_iters()
        );
    }

    #[test]
    fn counters_are_bit_stable_across_reruns() {
        // The regression gate's contract: identical config + seed ⇒ identical
        // counter tallies, even multithreaded (shards are deterministic and
        // per-shard sequences are solved sequentially).
        let run = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.threads = threads;
            Pipeline::new(cfg).run().unwrap().metrics.counters
        };
        let a = run(2);
        let b = run(2);
        assert_eq!(a, b);
        assert!(a.matvecs > 0 && a.precond_applies > 0 && a.ortho_flops > 0);
        assert!(a.harvests > 0, "{a:?}");
        let c = run(1);
        assert_eq!(c, run(1));
    }

    #[test]
    fn delta_instrumentation_records() {
        let mut cfg = small_cfg();
        cfg.instrument_delta = true;
        cfg.threads = 1;
        let r = Pipeline::new(cfg).run().unwrap();
        assert!(r.delta.count() > 0);
        for &d in r.delta.values() {
            assert!((0.0..=1.0 + 1e-9).contains(&d.max), "{d:?}");
            assert!((0.0..=1.0 + 1e-9).contains(&d.mean), "{d:?}");
            assert!(d.mean <= d.max + 1e-9, "{d:?}");
        }
        // δ values flow into the metrics histogram as well.
        assert_eq!(r.metrics.delta_hist.count(), r.delta.count());
    }

    #[test]
    fn multithreaded_matches_singlethreaded_solutions() {
        let dir1 = unique_path("pipe_t1");
        let dir2 = unique_path("pipe_t4");
        let mut cfg = small_cfg();
        cfg.solver.tol = 1e-10;
        cfg.threads = 1;
        cfg.out_dir = Some(dir1.clone());
        Pipeline::new(cfg.clone()).run().unwrap();
        cfg.threads = 4;
        cfg.out_dir = Some(dir2.clone());
        Pipeline::new(cfg).run().unwrap();
        let (_, s1, _) = crate::coordinator::dataset::load(&dir1).unwrap();
        let (_, s2, _) = crate::coordinator::dataset::load(&dir2).unwrap();
        // Same systems solved to 1e-10: solutions agree to ~1e-8 relative.
        for (a, b) in s1.data.iter().zip(&s2.data) {
            assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
        }
        let _ = std::fs::remove_dir_all(&dir1);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn trace_jsonl_is_valid_and_reproduces_metrics() {
        let dir = unique_path("pipe_trace_ds");
        let trace = unique_path("pipe_trace").with_extension("jsonl");
        let mut cfg = small_cfg();
        cfg.out_dir = Some(dir.clone());
        cfg.trace_out = Some(trace.clone());
        let r = Pipeline::new(cfg).run().unwrap();

        // Every line must parse as a standalone JSON object with an "ev" tag.
        let text = std::fs::read_to_string(&trace).unwrap();
        let mut ev_counts = std::collections::BTreeMap::<String, usize>::new();
        for line in text.lines() {
            let ev = Json::parse(line).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
            let tag = ev.get("ev").and_then(|t| t.as_str()).expect("missing ev tag").to_string();
            *ev_counts.entry(tag).or_insert(0) += 1;
        }
        assert_eq!(ev_counts.get("meta"), Some(&1));
        assert_eq!(ev_counts.get("run"), Some(&1));
        assert_eq!(ev_counts.get("solve"), Some(&12));
        assert_eq!(ev_counts.get("worker"), Some(&2));
        assert!(ev_counts.get("cycle").copied().unwrap_or(0) > 0, "{ev_counts:?}");
        assert!(ev_counts.get("recycle").copied().unwrap_or(0) > 0, "{ev_counts:?}");
        assert!(ev_counts.get("span").copied().unwrap_or(0) >= 4 + 2 + 12, "{ev_counts:?}");

        // `skr report` aggregation reproduces RunMetrics from the trace.
        let rep = TraceReport::from_file(&trace).unwrap();
        assert_eq!(rep.systems, r.metrics.systems);
        assert_eq!(rep.total_iters, r.metrics.total_iters);
        assert_eq!(rep.max_iter_hits, r.metrics.max_iter_hits);
        assert!((rep.mean_iters() - r.metrics.mean_iters()).abs() < 1e-9);
        assert!(
            (rep.mean_time() - r.metrics.mean_time()).abs() < 1e-9 * (1.0 + r.metrics.mean_time())
        );
        assert!((rep.rel_residual_worst - r.metrics.rel_residual_worst).abs() < 1e-20);
        assert!(
            (rep.backpressure_seconds() - r.metrics.backpressure_seconds).abs() < 1e-9,
            "{} vs {}",
            rep.backpressure_seconds(),
            r.metrics.backpressure_seconds
        );
        assert_eq!(rep.per_worker.len(), 2);
        for stage in ["gen", "sort", "shard", "solve"] {
            assert!(rep.stages.contains_key(stage), "missing stage {stage}: {:?}", rep.stages);
        }
        assert_eq!(rep.engines, vec!["SKR".to_string()]);

        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_file(&trace);
    }

    #[test]
    fn tracing_does_not_change_iteration_counts() {
        let mut cfg = small_cfg();
        cfg.threads = 1;
        let plain = Pipeline::new(cfg.clone()).run().unwrap();
        let trace = unique_path("pipe_bitident").with_extension("jsonl");
        cfg.trace_out = Some(trace.clone());
        let traced = Pipeline::new(cfg).run().unwrap();
        assert_eq!(plain.per_system.len(), traced.per_system.len());
        for ((id_a, a), (id_b, b)) in plain.per_system.iter().zip(&traced.per_system) {
            assert_eq!(id_a, id_b);
            assert_eq!(a.iters, b.iters, "sys {id_a}: tracing changed the iteration count");
            assert_eq!(a.stop, b.stop);
            assert_eq!(
                a.rel_residual.to_bits(),
                b.rel_residual.to_bits(),
                "sys {id_a}: tracing changed the residual"
            );
        }
        let _ = std::fs::remove_file(&trace);
    }
}
