//! The end-to-end SKR pipeline (paper Fig. 1/2):
//!
//! 1. **Parameter pass** — draw each instance's parameter matrix from its
//!    deterministic RNG stream (cheap; no matrices assembled).
//! 2. **Sort** — serialize by parameter similarity (Algorithm 1 / variants).
//! 3. **Shard** — contiguous batches per worker thread (Appendix E.2.2).
//! 4. **Solve** — each worker regenerates its systems on demand (bounded
//!    memory), solves sequentially with GCRO-DR recycling (or GMRES), and
//!    streams `(id, input, solution)` to the writer through a bounded
//!    channel — backpressure throttles the solvers if the writer lags.
//! 5. **Assemble** — `.npy` dataset + metrics.

use super::config::PipelineConfig;
use super::dataset::{DatasetSummary, DatasetWriter};
use super::delta::{delta_between, DeltaTracker};
use super::metrics::RunMetrics;
use super::scheduler::shard;
use super::sorter::sort_order;
use crate::pde::ProblemFamily;
use crate::solver::{gcrodr, gmres, Engine, Recycler, SolveStats};
use crate::util::prng::Rng;
use crate::util::timer::Timer;
use anyhow::{Context, Result};
use std::sync::mpsc::sync_channel;

/// Outcome of a pipeline run.
pub struct PipelineResult {
    pub metrics: RunMetrics,
    /// (original id, stats) in solve order, concatenated across workers.
    pub per_system: Vec<(usize, SolveStats)>,
    /// δ between consecutive recycle spaces (when instrumented).
    pub delta: DeltaTracker,
    pub dataset: Option<DatasetSummary>,
    /// The solve order that was used.
    pub order: Vec<usize>,
}

/// The pipeline entry point.
pub struct Pipeline {
    cfg: PipelineConfig,
    family: Box<dyn ProblemFamily>,
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Pipeline {
        let family = cfg.family.build_with(cfg.unknowns, cfg.grf_alpha);
        Pipeline { cfg, family }
    }

    /// Run the pipeline over a caller-constructed problem family (custom
    /// permeability maps, meshes, …); `cfg.family`/`cfg.unknowns` are then
    /// informational only.
    pub fn with_family(cfg: PipelineConfig, family: Box<dyn ProblemFamily>) -> Pipeline {
        Pipeline { cfg, family }
    }

    /// Access the problem family (for examples that need grid metadata).
    pub fn family(&self) -> &dyn ProblemFamily {
        self.family.as_ref()
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Run the full pipeline.
    pub fn run(&self) -> Result<PipelineResult> {
        let wall = Timer::start();
        let cfg = &self.cfg;
        let master = Rng::new(cfg.seed);

        // 1. Parameter pass.
        let gen_t = Timer::start();
        let params: Vec<Vec<f64>> = (0..cfg.count)
            .map(|i| self.family.sample_params(i, &mut master.split(i as u64)))
            .collect::<Result<_>>()?;
        let gen_seconds = gen_t.secs();

        // 2. Sort.
        let sort_t = Timer::start();
        let order = sort_order(&params, cfg.sort, cfg.seed ^ 0x5EED);
        let sort_seconds = sort_t.secs();

        // 3. Shard.
        let shards = shard(&order, cfg.threads);

        // 4. Solve (+ stream to writer).
        let input_dim = params.first().map_or(0, |p| p.len());
        let sol_dim = self.family.num_unknowns();
        let mut writer = cfg.out_dir.as_ref().map(|dir| {
            DatasetWriter::new(dir, cfg.count, input_dim, sol_dim, self.family.field_side())
        });

        let (tx, rx) = sync_channel::<(usize, Vec<f64>, Vec<f64>)>(cfg.queue_depth);
        let export = writer.is_some();
        let family = self.family.as_ref();

        let mut worker_outputs: Vec<WorkerOutput> = Vec::new();
        crossbeam_utils::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for batch in &shards {
                let tx = tx.clone();
                let master = master.clone();
                handles.push(scope.spawn(move |_| -> Result<WorkerOutput> {
                    solve_batch(family, cfg, batch, &master, export.then_some(tx))
                }));
            }
            drop(tx);
            // Writer loop on this thread (bounded channel = backpressure).
            if let Some(w) = writer.as_mut() {
                while let Ok((id, input, solution)) = rx.recv() {
                    w.put(id, &input, &solution)?;
                }
            } else {
                drop(rx);
            }
            for h in handles {
                worker_outputs.push(h.join().expect("worker panicked")?);
            }
            Ok(())
        })
        .expect("thread scope")?;

        // 5. Assemble.
        let mut metrics = RunMetrics::default();
        let mut per_system = Vec::with_capacity(cfg.count);
        let mut delta = DeltaTracker::default();
        for out in worker_outputs {
            for (id, s) in out.stats {
                metrics.absorb(&s);
                per_system.push((id, s));
            }
            for d in out.deltas {
                delta.record(d);
            }
        }
        metrics.gen_seconds = gen_seconds;
        metrics.sort_seconds = sort_seconds;
        metrics.wall_seconds = wall.secs();

        let dataset = match writer {
            Some(w) => Some(
                w.finalize(
                    self.family.name(),
                    vec![
                        ("engine", crate::util::json::Json::Str(cfg.engine.label().into())),
                        ("tol", crate::util::json::Json::Num(cfg.solver.tol)),
                        ("seed", crate::util::json::Json::Num(cfg.seed as f64)),
                    ],
                )
                .context("finalizing dataset")?,
            ),
            None => None,
        };

        Ok(PipelineResult { metrics, per_system, delta, dataset, order })
    }
}

struct WorkerOutput {
    stats: Vec<(usize, SolveStats)>,
    deltas: Vec<super::delta::Delta>,
}

/// Solve one contiguous batch sequentially, recycling across its systems.
fn solve_batch(
    family: &dyn ProblemFamily,
    cfg: &PipelineConfig,
    batch: &[usize],
    master: &Rng,
    tx: Option<std::sync::mpsc::SyncSender<(usize, Vec<f64>, Vec<f64>)>>,
) -> Result<WorkerOutput> {
    let mut rec = Recycler::new();
    let mut stats = Vec::with_capacity(batch.len());
    let mut deltas = Vec::new();
    let mut prev_space: Option<Vec<Vec<f64>>> = None;
    for &id in batch {
        let sys = family.sample(id, &mut master.split(id as u64))?;
        let p = cfg.precond.build(&sys.a)?;
        let mut x = vec![0.0; sys.b.len()];
        let s = match cfg.engine {
            Engine::Gmres => gmres(&sys.a, &sys.b, &mut x, p.as_ref(), &cfg.solver),
            Engine::SkrRecycle => gcrodr(&sys.a, &sys.b, &mut x, p.as_ref(), &cfg.solver, &mut rec),
        };
        if cfg.instrument_delta {
            if let (Some(prev), Some(cur)) = (&prev_space, &rec.ytilde) {
                if let Some(d) = delta_between(prev, cur) {
                    deltas.push(d);
                }
            }
            prev_space = rec.ytilde.clone();
        }
        if let Some(tx) = &tx {
            // Blocking send — backpressure when the writer is saturated.
            tx.send((id, family.input_field(&sys), x))
                .map_err(|_| anyhow::anyhow!("writer hung up"))?;
        }
        stats.push((id, s));
    }
    Ok(WorkerOutput { stats, deltas })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sorter::SortStrategy;
    use crate::pde::FamilyKind;
    use crate::precond::PrecondKind;

    fn small_cfg() -> PipelineConfig {
        PipelineConfig {
            family: FamilyKind::Darcy,
            unknowns: 100,
            count: 12,
            engine: Engine::SkrRecycle,
            precond: PrecondKind::Jacobi,
            sort: SortStrategy::Greedy,
            threads: 2,
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn runs_end_to_end_and_converges() {
        let p = Pipeline::new(small_cfg());
        let r = p.run().unwrap();
        assert_eq!(r.metrics.systems, 12);
        assert_eq!(r.per_system.len(), 12);
        assert_eq!(r.metrics.max_iter_hits, 0);
        assert!(r.metrics.mean_iters() > 0.0);
    }

    #[test]
    fn exports_complete_dataset() {
        let dir = std::env::temp_dir().join("skr_pipe_ds");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = small_cfg();
        cfg.out_dir = Some(dir.clone());
        let r = Pipeline::new(cfg).run().unwrap();
        let ds = r.dataset.unwrap();
        assert_eq!(ds.count, 12);
        let (ins, sols, _) = crate::coordinator::dataset::load(&dir).unwrap();
        assert_eq!(ins.shape[0], 12);
        assert_eq!(sols.shape, vec![12, 100]);
        // Solutions should be nontrivial.
        assert!(sols.data.iter().any(|&v| v.abs() > 1e-12));
    }

    #[test]
    fn skr_beats_gmres_on_iterations() {
        // Needs a problem hard enough that GMRES restarts several times —
        // recycling overhead (k seed matvecs + harvest cycle) only amortizes
        // then (the paper's sizes start at n = 2500).
        let mut cfg = small_cfg();
        cfg.unknowns = 625;
        cfg.solver.tol = 1e-9;
        cfg.count = 10;
        cfg.threads = 1;
        let skr = Pipeline::new(cfg.clone()).run().unwrap();
        cfg.engine = Engine::Gmres;
        let gm = Pipeline::new(cfg).run().unwrap();
        assert!(
            skr.metrics.mean_iters() < gm.metrics.mean_iters(),
            "SKR {} vs GMRES {}",
            skr.metrics.mean_iters(),
            gm.metrics.mean_iters()
        );
    }

    #[test]
    fn delta_instrumentation_records() {
        let mut cfg = small_cfg();
        cfg.instrument_delta = true;
        cfg.threads = 1;
        let r = Pipeline::new(cfg).run().unwrap();
        assert!(r.delta.count() > 0);
        for &d in r.delta.values() {
            assert!((0.0..=1.0 + 1e-9).contains(&d.max), "{d:?}");
            assert!((0.0..=1.0 + 1e-9).contains(&d.mean), "{d:?}");
            assert!(d.mean <= d.max + 1e-9, "{d:?}");
        }
    }

    #[test]
    fn multithreaded_matches_singlethreaded_solutions() {
        let dir1 = std::env::temp_dir().join("skr_pipe_t1");
        let dir2 = std::env::temp_dir().join("skr_pipe_t4");
        let _ = std::fs::remove_dir_all(&dir1);
        let _ = std::fs::remove_dir_all(&dir2);
        let mut cfg = small_cfg();
        cfg.solver.tol = 1e-10;
        cfg.threads = 1;
        cfg.out_dir = Some(dir1.clone());
        Pipeline::new(cfg.clone()).run().unwrap();
        cfg.threads = 4;
        cfg.out_dir = Some(dir2.clone());
        Pipeline::new(cfg).run().unwrap();
        let (_, s1, _) = crate::coordinator::dataset::load(&dir1).unwrap();
        let (_, s2, _) = crate::coordinator::dataset::load(&dir2).unwrap();
        // Same systems solved to 1e-10: solutions agree to ~1e-8 relative.
        for (a, b) in s1.data.iter().zip(&s2.data) {
            assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }
}
