//! Cooperative run control — shared between a [`super::Pipeline`] run and
//! whoever supervises it (the `skr serve` job workers, a future TUI, tests).
//!
//! A [`RunControl`] carries two things across the thread boundary:
//!
//! * a **cancellation token**: `cancel()` flips an atomic flag that every
//!   solve worker checks *between* system solves, so a cancelled run stops
//!   within one solve and never finalizes its dataset;
//! * **live progress counters**: systems done/total plus the three reuse
//!   tallies, updated lock-free after each system so `GET /jobs/:id` can
//!   report mid-flight state without touching the run.
//!
//! All counters are monotone and relaxed — readers may lag a solve or two
//! behind, which is fine for observability.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Cancellation token + live progress counters for one pipeline run.
#[derive(Debug, Default)]
pub struct RunControl {
    cancelled: AtomicBool,
    total: AtomicUsize,
    done: AtomicUsize,
    sparsity_reuse: AtomicUsize,
    symbolic_reuse: AtomicUsize,
    workspace_reuse: AtomicUsize,
}

/// A point-in-time view of a run's progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgressSnapshot {
    pub done: usize,
    pub total: usize,
    pub sparsity_reuse: usize,
    pub symbolic_reuse: usize,
    pub workspace_reuse: usize,
}

impl RunControl {
    pub fn new() -> RunControl {
        RunControl::default()
    }

    /// Request cancellation; the run stops after the in-flight system solves.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Called once at run start with the system count.
    pub fn set_total(&self, total: usize) {
        self.total.store(total, Ordering::Relaxed);
    }

    /// Called by a solve worker after each completed system.
    pub fn note_system(&self, sparsity_reused: bool, symbolic_reused: bool, workspace_reused: bool) {
        self.done.fetch_add(1, Ordering::Relaxed);
        if sparsity_reused {
            self.sparsity_reuse.fetch_add(1, Ordering::Relaxed);
        }
        if symbolic_reused {
            self.symbolic_reuse.fetch_add(1, Ordering::Relaxed);
        }
        if workspace_reused {
            self.workspace_reuse.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn progress(&self) -> ProgressSnapshot {
        ProgressSnapshot {
            done: self.done.load(Ordering::Relaxed),
            total: self.total.load(Ordering::Relaxed),
            sparsity_reuse: self.sparsity_reuse.load(Ordering::Relaxed),
            symbolic_reuse: self.symbolic_reuse.load(Ordering::Relaxed),
            workspace_reuse: self.workspace_reuse.load(Ordering::Relaxed),
        }
    }
}

/// Marker error a cancelled [`super::Pipeline::run_with`] returns; supervisors
/// downcast (`err.downcast_ref::<Cancelled>()`) to tell cancellation from
/// genuine failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cancelled;

impl std::fmt::Display for Cancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "run cancelled")
    }
}

impl std::error::Error for Cancelled {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let ctl = RunControl::new();
        ctl.set_total(5);
        ctl.note_system(true, true, false);
        ctl.note_system(false, true, true);
        let p = ctl.progress();
        assert_eq!(p.done, 2);
        assert_eq!(p.total, 5);
        assert_eq!(p.sparsity_reuse, 1);
        assert_eq!(p.symbolic_reuse, 2);
        assert_eq!(p.workspace_reuse, 1);
    }

    #[test]
    fn cancel_flag_flips_once() {
        let ctl = RunControl::new();
        assert!(!ctl.is_cancelled());
        ctl.cancel();
        assert!(ctl.is_cancelled());
    }

    #[test]
    fn cancelled_error_downcasts() {
        let e = anyhow::Error::new(Cancelled);
        assert!(e.downcast_ref::<Cancelled>().is_some());
        assert_eq!(e.to_string(), "run cancelled");
    }
}
