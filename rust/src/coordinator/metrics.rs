//! Aggregated run metrics — what the paper's tables report: mean per-system
//! solve time, mean iteration count, max-iteration incidence, wall time —
//! plus the observability extensions: final-residual aggregation,
//! writer-backpressure totals, and Prometheus-style histograms of
//! iterations, solve seconds and the δ subspace distance.

use crate::obs::Histogram;
use crate::solver::{SolveCounters, SolveStats, StopReason};

/// Aggregate over a batch of per-system stats.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub systems: usize,
    /// Sum of per-system solver seconds (excludes generation/sort).
    pub solve_seconds: f64,
    pub total_iters: usize,
    /// Count of systems that hit the iteration cap (Fig. 13's metric).
    pub max_iter_hits: usize,
    pub breakdowns: usize,
    /// End-to-end wall seconds for the whole pipeline run.
    pub wall_seconds: f64,
    /// Seconds spent in the sorting stage.
    pub sort_seconds: f64,
    /// Seconds spent generating/assembling systems.
    pub gen_seconds: f64,
    /// Worst (largest) final relative residual over all systems.
    pub rel_residual_worst: f64,
    /// Sum of final relative residuals (drives [`RunMetrics::mean_rel_residual`]).
    pub rel_residual_sum: f64,
    /// Total seconds workers spent blocked in the bounded writer channel.
    pub backpressure_seconds: f64,
    /// Systems whose matrix shared the previous system's `Arc<Sparsity>` by
    /// pointer (the PDE families' shared-pattern fast path).
    pub sparsity_reuse: usize,
    /// Systems whose preconditioner skipped the symbolic phase (fill
    /// positions, subdomain maps, block layouts) and only refactored values.
    pub symbolic_reuse: usize,
    /// Solves that reran on pooled Krylov buffers without reallocation.
    pub workspace_reuse: usize,
    /// Deterministic solver op counters (matvecs, preconditioner applies,
    /// orthogonalization flops, recycle events) summed over every solve —
    /// the bit-stable metrics `skr bench` gates on.
    pub counters: SolveCounters,
    /// Per-system inner-iteration histogram.
    pub iters_hist: Histogram,
    /// Per-system solve-seconds histogram.
    pub time_hist: Histogram,
    /// δ subspace-distance histogram (populated when `--delta` instruments
    /// the run; spectral flavour).
    pub delta_hist: Histogram,
}

impl Default for RunMetrics {
    fn default() -> Self {
        RunMetrics {
            systems: 0,
            solve_seconds: 0.0,
            total_iters: 0,
            max_iter_hits: 0,
            breakdowns: 0,
            wall_seconds: 0.0,
            sort_seconds: 0.0,
            gen_seconds: 0.0,
            rel_residual_worst: 0.0,
            rel_residual_sum: 0.0,
            backpressure_seconds: 0.0,
            sparsity_reuse: 0,
            symbolic_reuse: 0,
            workspace_reuse: 0,
            counters: SolveCounters::default(),
            iters_hist: Histogram::iters_buckets(),
            time_hist: Histogram::seconds_buckets(),
            delta_hist: Histogram::unit_buckets(),
        }
    }
}

impl RunMetrics {
    pub fn absorb(&mut self, s: &SolveStats) {
        self.systems += 1;
        self.solve_seconds += s.seconds;
        self.total_iters += s.iters;
        match s.stop {
            StopReason::MaxIters => self.max_iter_hits += 1,
            StopReason::Breakdown => self.breakdowns += 1,
            StopReason::Converged => {}
        }
        if s.rel_residual.is_finite() {
            self.rel_residual_sum += s.rel_residual;
            if s.rel_residual > self.rel_residual_worst {
                self.rel_residual_worst = s.rel_residual;
            }
        }
        self.iters_hist.observe(s.iters as f64);
        self.time_hist.observe(s.seconds);
    }

    /// Record one δ subspace distance (spectral flavour).
    pub fn record_delta(&mut self, delta: f64) {
        self.delta_hist.observe(delta);
    }

    /// Mean solve seconds per system.
    pub fn mean_time(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.solve_seconds / self.systems as f64
        }
    }

    /// Mean iterations per system.
    pub fn mean_iters(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.total_iters as f64 / self.systems as f64
        }
    }

    /// Fraction of systems that failed to converge within the cap.
    pub fn max_iter_rate(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.max_iter_hits as f64 / self.systems as f64
        }
    }

    /// Mean final relative residual over all systems.
    pub fn mean_rel_residual(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.rel_residual_sum / self.systems as f64
        }
    }

    /// Merge two aggregates (for multi-worker reduction).
    pub fn merge(&mut self, other: &RunMetrics) {
        self.systems += other.systems;
        self.solve_seconds += other.solve_seconds;
        self.total_iters += other.total_iters;
        self.max_iter_hits += other.max_iter_hits;
        self.breakdowns += other.breakdowns;
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
        self.sort_seconds += other.sort_seconds;
        self.gen_seconds += other.gen_seconds;
        self.rel_residual_worst = self.rel_residual_worst.max(other.rel_residual_worst);
        self.rel_residual_sum += other.rel_residual_sum;
        self.backpressure_seconds += other.backpressure_seconds;
        self.sparsity_reuse += other.sparsity_reuse;
        self.symbolic_reuse += other.symbolic_reuse;
        self.workspace_reuse += other.workspace_reuse;
        self.counters.merge(&other.counters);
        self.iters_hist.merge(&other.iters_hist);
        self.time_hist.merge(&other.time_hist);
        self.delta_hist.merge(&other.delta_hist);
    }

    /// Prometheus text-format snapshot of the whole aggregate (counters,
    /// gauges and the three histograms) — scrape-compatible, also emitted
    /// verbatim by `skr report --prometheus`.
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        counter("skr_systems_total", "systems solved", self.systems as f64);
        counter("skr_iters_total", "inner solver iterations", self.total_iters as f64);
        counter(
            "skr_max_iter_hits_total",
            "systems that hit the iteration cap",
            self.max_iter_hits as f64,
        );
        counter("skr_breakdowns_total", "systems that ended in breakdown", self.breakdowns as f64);
        counter("skr_solve_seconds_total", "seconds in the solve stage", self.solve_seconds);
        counter("skr_gen_seconds_total", "seconds generating systems", self.gen_seconds);
        counter("skr_sort_seconds_total", "seconds sorting", self.sort_seconds);
        counter(
            "skr_backpressure_seconds_total",
            "seconds workers blocked on the writer channel",
            self.backpressure_seconds,
        );
        counter(
            "skr_sparsity_reuse_total",
            "systems sharing the previous matrix's Arc<Sparsity>",
            self.sparsity_reuse as f64,
        );
        counter(
            "skr_symbolic_reuse_total",
            "preconditioner builds that skipped the symbolic phase",
            self.symbolic_reuse as f64,
        );
        counter(
            "skr_workspace_reuse_total",
            "solves rerun on pooled Krylov buffers",
            self.workspace_reuse as f64,
        );
        counter("skr_matvecs_total", "sparse operator applies", self.counters.matvecs as f64);
        counter(
            "skr_precond_applies_total",
            "preconditioner applies",
            self.counters.precond_applies as f64,
        );
        counter(
            "skr_ortho_flops_total",
            "orthogonalization flops",
            self.counters.ortho_flops as f64,
        );
        counter(
            "skr_recycle_reseeds_total",
            "recycle spaces re-orthonormalized for a changed operator",
            self.counters.recycle_reseeds as f64,
        );
        counter(
            "skr_recycle_carries_total",
            "recycle spaces carried on an operator fingerprint match",
            self.counters.recycle_carries as f64,
        );
        counter(
            "skr_harvests_total",
            "harmonic-Ritz recycle-space harvests",
            self.counters.harvests as f64,
        );
        let _ = writeln!(out, "# TYPE skr_wall_seconds gauge");
        let _ = writeln!(out, "skr_wall_seconds {}", self.wall_seconds);
        let _ = writeln!(out, "# TYPE skr_rel_residual_worst gauge");
        let _ = writeln!(out, "skr_rel_residual_worst {}", self.rel_residual_worst);
        self.iters_hist.prometheus("skr_solve_iters", &mut out);
        self.time_hist.prometheus("skr_solve_seconds", &mut out);
        self.delta_hist.prometheus("skr_delta", &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(iters: usize, secs: f64, stop: StopReason) -> SolveStats {
        SolveStats { iters, seconds: secs, rel_residual: 0.0, stop, trace: vec![] }
    }

    #[test]
    fn aggregates_and_merges() {
        let mut m = RunMetrics::default();
        m.absorb(&stat(10, 1.0, StopReason::Converged));
        m.absorb(&stat(30, 3.0, StopReason::MaxIters));
        assert_eq!(m.systems, 2);
        assert!((m.mean_time() - 2.0).abs() < 1e-15);
        assert!((m.mean_iters() - 20.0).abs() < 1e-15);
        assert!((m.max_iter_rate() - 0.5).abs() < 1e-15);

        let mut other = RunMetrics::default();
        other.absorb(&stat(20, 2.0, StopReason::Converged));
        m.merge(&other);
        assert_eq!(m.systems, 3);
        assert_eq!(m.total_iters, 60);
        assert_eq!(m.iters_hist.count(), 3);
        assert_eq!(m.time_hist.count(), 3);
    }

    #[test]
    fn residual_aggregation_tracks_worst_and_mean() {
        let mut m = RunMetrics::default();
        for rel in [1e-9, 5e-9, 2e-10] {
            let mut s = stat(5, 0.1, StopReason::Converged);
            s.rel_residual = rel;
            m.absorb(&s);
        }
        // A non-finite residual must not poison the aggregate.
        let mut bad = stat(5, 0.1, StopReason::Breakdown);
        bad.rel_residual = f64::NAN;
        m.absorb(&bad);
        assert!((m.rel_residual_worst - 5e-9).abs() < 1e-24);
        assert!((m.mean_rel_residual() - (1e-9 + 5e-9 + 2e-10) / 4.0).abs() < 1e-24);
    }

    #[test]
    fn merge_combines_residuals_and_backpressure() {
        let mut a = RunMetrics::default();
        let mut s = stat(5, 0.1, StopReason::Converged);
        s.rel_residual = 1e-9;
        a.absorb(&s);
        a.backpressure_seconds = 0.5;
        a.sparsity_reuse = 3;
        a.symbolic_reuse = 2;
        a.workspace_reuse = 1;
        a.record_delta(0.25);

        let mut b = RunMetrics::default();
        let mut s2 = stat(7, 0.2, StopReason::Converged);
        s2.rel_residual = 3e-9;
        b.absorb(&s2);
        b.backpressure_seconds = 0.25;
        b.sparsity_reuse = 4;
        b.symbolic_reuse = 4;
        b.workspace_reuse = 4;
        b.record_delta(0.85);

        a.merge(&b);
        assert!((a.rel_residual_worst - 3e-9).abs() < 1e-24);
        assert!((a.backpressure_seconds - 0.75).abs() < 1e-15);
        assert_eq!(a.delta_hist.count(), 2);
        assert_eq!(a.sparsity_reuse, 7);
        assert_eq!(a.symbolic_reuse, 6);
        assert_eq!(a.workspace_reuse, 5);
    }

    #[test]
    fn prometheus_snapshot_contains_all_series() {
        let mut m = RunMetrics::default();
        m.absorb(&stat(42, 0.5, StopReason::Converged));
        m.backpressure_seconds = 0.125;
        m.sparsity_reuse = 9;
        m.symbolic_reuse = 8;
        m.workspace_reuse = 7;
        m.counters.matvecs = 44;
        m.counters.precond_applies = 43;
        m.counters.ortho_flops = 123456;
        m.counters.recycle_carries = 2;
        m.record_delta(0.5);
        let text = m.prometheus_text();
        for series in [
            "skr_systems_total 1",
            "skr_iters_total 42",
            "skr_backpressure_seconds_total 0.125",
            "skr_sparsity_reuse_total 9",
            "skr_symbolic_reuse_total 8",
            "skr_workspace_reuse_total 7",
            "skr_matvecs_total 44",
            "skr_precond_applies_total 43",
            "skr_ortho_flops_total 123456",
            "skr_recycle_reseeds_total 0",
            "skr_recycle_carries_total 2",
            "skr_harvests_total 0",
            "skr_solve_iters_bucket",
            "skr_solve_seconds_bucket",
            "skr_delta_bucket",
            "skr_rel_residual_worst",
        ] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
    }
}
