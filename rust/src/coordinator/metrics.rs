//! Aggregated run metrics — what the paper's tables report: mean per-system
//! solve time, mean iteration count, max-iteration incidence, wall time.

use crate::solver::{SolveStats, StopReason};

/// Aggregate over a batch of per-system stats.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub systems: usize,
    /// Sum of per-system solver seconds (excludes generation/sort).
    pub solve_seconds: f64,
    pub total_iters: usize,
    /// Count of systems that hit the iteration cap (Fig. 13's metric).
    pub max_iter_hits: usize,
    pub breakdowns: usize,
    /// End-to-end wall seconds for the whole pipeline run.
    pub wall_seconds: f64,
    /// Seconds spent in the sorting stage.
    pub sort_seconds: f64,
    /// Seconds spent generating/assembling systems.
    pub gen_seconds: f64,
}

impl RunMetrics {
    pub fn absorb(&mut self, s: &SolveStats) {
        self.systems += 1;
        self.solve_seconds += s.seconds;
        self.total_iters += s.iters;
        match s.stop {
            StopReason::MaxIters => self.max_iter_hits += 1,
            StopReason::Breakdown => self.breakdowns += 1,
            StopReason::Converged => {}
        }
    }

    /// Mean solve seconds per system.
    pub fn mean_time(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.solve_seconds / self.systems as f64
        }
    }

    /// Mean iterations per system.
    pub fn mean_iters(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.total_iters as f64 / self.systems as f64
        }
    }

    /// Fraction of systems that failed to converge within the cap.
    pub fn max_iter_rate(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.max_iter_hits as f64 / self.systems as f64
        }
    }

    /// Merge two aggregates (for multi-worker reduction).
    pub fn merge(&mut self, other: &RunMetrics) {
        self.systems += other.systems;
        self.solve_seconds += other.solve_seconds;
        self.total_iters += other.total_iters;
        self.max_iter_hits += other.max_iter_hits;
        self.breakdowns += other.breakdowns;
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
        self.sort_seconds += other.sort_seconds;
        self.gen_seconds += other.gen_seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(iters: usize, secs: f64, stop: StopReason) -> SolveStats {
        SolveStats { iters, seconds: secs, rel_residual: 0.0, stop, trace: vec![] }
    }

    #[test]
    fn aggregates_and_merges() {
        let mut m = RunMetrics::default();
        m.absorb(&stat(10, 1.0, StopReason::Converged));
        m.absorb(&stat(30, 3.0, StopReason::MaxIters));
        assert_eq!(m.systems, 2);
        assert!((m.mean_time() - 2.0).abs() < 1e-15);
        assert!((m.mean_iters() - 20.0).abs() < 1e-15);
        assert!((m.max_iter_rate() - 0.5).abs() < 1e-15);

        let mut other = RunMetrics::default();
        other.absorb(&stat(20, 2.0, StopReason::Converged));
        m.merge(&other);
        assert_eq!(m.systems, 3);
        assert_eq!(m.total_iters, 60);
    }
}
