//! Paper figure reproductions — each emits the data series behind a figure
//! as CSV (plus a printed summary), since the container has no plotting:
//!
//! * `conv`       — Fig 1 (right): accuracy-vs-time/iterations curves.
//! * `similarity` — Figs 4/5 and 9/10: solution distance vs parameter
//!   distance for close and divergent parameter pairs (Darcy + Helmholtz).
//! * `sortpairs`  — Figs 7/8: neighbour solution distance before/after sort.
//! * `f11`/`f12`  — Figs 11/12: per-preconditioner convergence curves and
//!   the high-precision slope fits.
//! * `f13`        — Fig 13: fraction of solves hitting the iteration cap.

use super::results_dir;
use crate::coordinator::sorter::{dist2, sort_order, SortStrategy};
use crate::coordinator::{Pipeline, PipelineConfig};
use crate::obs::TraceReport;
use crate::pde::{generate, FamilyKind};
use crate::precond::PrecondKind;
use crate::solver::{solve_sequence, Engine, SolverConfig};
use crate::util::args::Args;
use crate::util::table::Table;
use crate::util::{mean, ols_slope};
use anyhow::Result;

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let which = args.str_or("fig", "all");
    let full = args.flag("full");
    let n = args.num_or("n", if full { 10_000 } else { 1600 });
    let count = args.num_or("count", if full { 50 } else { 12 });
    let seed = args.num_or("seed", 0u64);
    if matches!(which.as_str(), "all" | "conv") {
        fig_conv(n, count, seed)?;
    }
    if matches!(which.as_str(), "all" | "similarity") {
        fig_similarity(n.min(2500), count.max(16), seed)?;
    }
    if matches!(which.as_str(), "all" | "sortpairs") {
        fig_sortpairs(n.min(2500), count.max(16), seed)?;
    }
    if matches!(which.as_str(), "all" | "f11" | "f12") {
        fig_11_12(n, count, seed)?;
    }
    if matches!(which.as_str(), "all" | "f13") {
        fig_13(n, count, seed)?;
    }
    Ok(())
}

/// Fig 1 (right): residual trace (accuracy vs estimated time and iters).
pub fn fig_conv(n: usize, count: usize, seed: u64) -> Result<()> {
    let mut t = Table::new(
        "Fig 1 (right) — accuracy vs cumulative cost (Darcy, Jacobi)",
        &["engine", "system", "iters", "est_seconds", "rel_residual"],
    );
    for engine in [Engine::Gmres, Engine::SkrRecycle] {
        let mut cfg = PipelineConfig::default();
        cfg.family = FamilyKind::Darcy;
        cfg.unknowns = n;
        cfg.count = count;
        cfg.precond = PrecondKind::Jacobi;
        cfg.engine = engine;
        cfg.sort = if engine == Engine::SkrRecycle { SortStrategy::Greedy } else { SortStrategy::None };
        cfg.solver.tol = 1e-8;
        cfg.solver.record_trace = true;
        cfg.seed = seed;
        let r = Pipeline::new(cfg).run()?;
        for (sys_id, stats) in &r.per_system {
            let per_iter = if stats.iters > 0 { stats.seconds / stats.iters as f64 } else { 0.0 };
            for &(it, rel) in &stats.trace {
                t.row(vec![
                    engine.label().to_string(),
                    sys_id.to_string(),
                    it.to_string(),
                    format!("{:.6}", it as f64 * per_iter),
                    format!("{rel:.3e}"),
                ]);
            }
        }
        println!(
            "fig1[{}]: mean {:.4}s/system, {:.0} iters/system",
            engine.label(),
            r.metrics.mean_time(),
            r.metrics.mean_iters()
        );
    }
    t.write_csv(&results_dir().join("fig1_convergence.csv"))?;
    println!("→ results/fig1_convergence.csv");
    Ok(())
}

/// Figs 4/5 + 9/10: parameter distance vs solution distance.
pub fn fig_similarity(n: usize, count: usize, seed: u64) -> Result<()> {
    let mut t = Table::new(
        "Figs 4/5, 9/10 — parameter vs solution distance",
        &["family", "pair", "param_dist", "solution_dist"],
    );
    for family in [FamilyKind::Darcy, FamilyKind::Helmholtz] {
        let fam = family.build(n);
        let systems = generate(fam.as_ref(), count, seed)?;
        let cfg = SolverConfig::default().with_tol(1e-8);
        let sols = solve_sequence(&systems, Engine::SkrRecycle, PrecondKind::Jacobi, &cfg)?;
        // All pairs (count is small): param distance vs solution distance.
        let mut pairs: Vec<(f64, f64)> = Vec::new();
        for i in 0..count {
            for j in i + 1..count {
                let pd = dist2(&systems[i].params, &systems[j].params).sqrt();
                let sd: f64 = sols[i]
                    .0
                    .iter()
                    .zip(&sols[j].0)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                pairs.push((pd, sd));
                t.row(vec![
                    family.label().to_string(),
                    format!("{i}-{j}"),
                    format!("{pd:.4}"),
                    format!("{sd:.4}"),
                ]);
            }
        }
        // Pearson correlation — the figures' qualitative claim.
        let (xs, ys): (Vec<f64>, Vec<f64>) = pairs.iter().copied().unzip();
        let r = pearson(&xs, &ys);
        let closest = pairs.iter().cloned().fold((f64::INFINITY, 0.0), |a, b| if b.0 < a.0 { b } else { a });
        let farthest = pairs.iter().cloned().fold((f64::NEG_INFINITY, 0.0), |a, b| if b.0 > a.0 { b } else { a });
        println!(
            "{}: corr(param dist, solution dist) = {r:.3}; closest pair Δsol={:.3}, farthest Δsol={:.3}",
            family.label(),
            closest.1,
            farthest.1
        );
    }
    t.write_csv(&results_dir().join("fig4_5_9_10_similarity.csv"))?;
    println!("→ results/fig4_5_9_10_similarity.csv");
    Ok(())
}

/// Figs 7/8: consecutive-pair solution distance before vs after sorting.
pub fn fig_sortpairs(n: usize, count: usize, seed: u64) -> Result<()> {
    let fam = FamilyKind::Poisson.build(n);
    let systems = generate(fam.as_ref(), count, seed)?;
    let cfg = SolverConfig::default().with_tol(1e-8);
    let sols = solve_sequence(&systems, Engine::SkrRecycle, PrecondKind::Jacobi, &cfg)?;
    let params: Vec<Vec<f64>> = systems.iter().map(|s| s.params.clone()).collect();
    let sol_dist = |i: usize, j: usize| -> f64 {
        sols[i].0.iter().zip(&sols[j].0).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt()
    };
    let chain = |order: &[usize]| -> Vec<f64> {
        order.windows(2).map(|w| sol_dist(w[0], w[1])).collect()
    };
    let unsorted: Vec<usize> = (0..count).collect();
    let sorted = sort_order(&params, SortStrategy::Greedy, seed);
    let before = chain(&unsorted);
    let after = chain(&sorted);

    let mut t = Table::new(
        "Figs 7/8 — neighbour solution distance (Poisson)",
        &["order", "pair_index", "solution_dist"],
    );
    for (i, d) in before.iter().enumerate() {
        t.row(vec!["unsorted".into(), i.to_string(), format!("{d:.4}")]);
    }
    for (i, d) in after.iter().enumerate() {
        t.row(vec!["sorted".into(), i.to_string(), format!("{d:.4}")]);
    }
    t.write_csv(&results_dir().join("fig7_8_sortpairs.csv"))?;
    println!(
        "Poisson neighbour Δsol: unsorted mean {:.4} → sorted mean {:.4} (−{:.0}%)",
        mean(&before),
        mean(&after),
        (1.0 - mean(&after) / mean(&before)) * 100.0
    );
    println!("→ results/fig7_8_sortpairs.csv");
    Ok(())
}

/// Figs 11/12: accuracy-vs-cost curves per preconditioner + slope fits.
///
/// The series are read back from each run's JSONL trace (`skr report`'s
/// aggregation path) rather than the in-memory metrics — the figure data
/// and the trace tooling can never drift apart.
pub fn fig_11_12(n: usize, count: usize, seed: u64) -> Result<()> {
    let tols = [1e-2, 1e-3, 1e-4, 1e-5, 1e-6, 1e-7];
    let preconds = [PrecondKind::None, PrecondKind::Jacobi, PrecondKind::Sor, PrecondKind::Ilu];
    let trace_path = results_dir().join("fig11_12_trace.jsonl");
    let mut t = Table::new(
        "Figs 11/12 — Helmholtz accuracy vs mean cost",
        &["precond", "engine", "tol", "mean_seconds", "mean_iters"],
    );
    let mut slopes = Table::new(
        "Figs 11/12 (right) — high-precision slope fits (3 tightest tols)",
        &["precond", "engine", "slope_time", "slope_iters"],
    );
    for precond in preconds {
        for engine in [Engine::Gmres, Engine::SkrRecycle] {
            let mut times = Vec::new();
            let mut iters = Vec::new();
            for &tol in &tols {
                let mut cfg = PipelineConfig::default();
                cfg.family = FamilyKind::Helmholtz;
                cfg.unknowns = n;
                cfg.count = count;
                cfg.precond = precond;
                cfg.engine = engine;
                cfg.sort = if engine == Engine::SkrRecycle {
                    SortStrategy::Greedy
                } else {
                    SortStrategy::None
                };
                cfg.solver.tol = tol;
                cfg.seed = seed;
                cfg.trace_out = Some(trace_path.clone());
                Pipeline::new(cfg).run()?;
                let rep = TraceReport::from_file(&trace_path)?;
                times.push(rep.mean_time());
                iters.push(rep.mean_iters());
                t.row(vec![
                    precond.label().into(),
                    engine.label().into(),
                    format!("{tol:.0e}"),
                    format!("{:.4}", rep.mean_time()),
                    format!("{:.1}", rep.mean_iters()),
                ]);
            }
            // Slope of log10(accuracy) against cost over the 3 tightest tols
            // (the paper's linear fit isolating the superlinear phase).
            let logacc: Vec<f64> = tols.iter().map(|t| t.log10()).collect();
            let k = tols.len() - 3;
            let st = ols_slope(&times[k..], &logacc[k..]);
            let si = ols_slope(&iters[k..], &logacc[k..]);
            slopes.row(vec![
                precond.label().into(),
                engine.label().into(),
                format!("{st:.3}"),
                format!("{si:.5}"),
            ]);
            println!(
                "f11/12 [{} {}]: slope_time {st:.3} dec/s, slope_iters {si:.5} dec/iter",
                precond.label(),
                engine.label()
            );
        }
    }
    let _ = std::fs::remove_file(&trace_path);
    t.write_csv(&results_dir().join("fig11_12_curves.csv"))?;
    slopes.write_csv(&results_dir().join("fig11_12_slopes.csv"))?;
    print!("{}", slopes.render());
    println!("→ results/fig11_12_curves.csv, results/fig11_12_slopes.csv");
    Ok(())
}

/// Fig 13: fraction of solves hitting the iteration cap.
pub fn fig_13(n: usize, count: usize, seed: u64) -> Result<()> {
    let tols = [1e-2, 1e-4, 1e-6, 1e-8];
    // A deliberately tight cap puts the baseline under stress, as in the
    // paper (cap 10⁴ at n 10⁴; scaled down with n here).
    let cap = (n / 2).max(500);
    let mut t = Table::new(
        &format!("Fig 13 — fraction of solves hitting the {cap}-iteration cap (Darcy)"),
        &["tol", "GMRES_frac", "SKR_frac"],
    );
    for &tol in &tols {
        let mut fracs = Vec::new();
        for engine in [Engine::Gmres, Engine::SkrRecycle] {
            let mut cfg = PipelineConfig::default();
            cfg.family = FamilyKind::Darcy;
            cfg.unknowns = n;
            cfg.count = count;
            cfg.precond = PrecondKind::Jacobi;
            cfg.engine = engine;
            cfg.sort = if engine == Engine::SkrRecycle {
                SortStrategy::Greedy
            } else {
                SortStrategy::None
            };
            cfg.solver.tol = tol;
            cfg.solver.max_iters = cap;
            cfg.seed = seed;
            let r = Pipeline::new(cfg).run()?;
            fracs.push(r.metrics.max_iter_rate());
        }
        println!("f13 tol={tol:.0e}: GMRES {:.0}% vs SKR {:.0}%", fracs[0] * 100.0, fracs[1] * 100.0);
        t.row(vec![format!("{tol:.0e}"), format!("{:.3}", fracs[0]), format!("{:.3}", fracs[1])]);
    }
    t.write_csv(&results_dir().join("fig13_stability.csv"))?;
    println!("→ results/fig13_stability.csv");
    Ok(())
}

fn pearson(x: &[f64], y: &[f64]) -> f64 {
    let mx = mean(x);
    let my = mean(y);
    let cov: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let vx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let vy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx * vy).sqrt()
    }
}
