//! `skr compare` — run the same configuration under GMRES and SKR and print
//! the speedup pair; the smallest useful readout and the building block the
//! table harnesses loop over.

use super::{speedup, Speedup};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::{Pipeline, PipelineConfig, SortStrategy};
use crate::solver::Engine;
use crate::util::args::Args;
use anyhow::Result;

/// Derive a per-engine trace path: `t.jsonl` → `t.gmres.jsonl` (the two
/// engines of a compare run must not clobber one file).
fn engine_trace_path(base: &std::path::Path, tag: &str) -> std::path::PathBuf {
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    let name = match base.extension().and_then(|s| s.to_str()) {
        Some(ext) => format!("{stem}.{tag}.{ext}"),
        None => format!("{stem}.{tag}"),
    };
    base.with_file_name(name)
}

/// Run one configuration under both engines; returns (gmres, skr) metrics.
pub fn run_pair(base: &PipelineConfig) -> Result<(RunMetrics, RunMetrics)> {
    let mut gm_cfg = base.clone();
    gm_cfg.engine = Engine::Gmres;
    gm_cfg.sort = SortStrategy::None; // the baseline solves in stream order
    gm_cfg.out_dir = None;
    gm_cfg.trace_out = base.trace_out.as_ref().map(|p| engine_trace_path(p, "gmres"));
    let gm = Pipeline::new(gm_cfg).run()?.metrics;

    let mut skr_cfg = base.clone();
    skr_cfg.engine = Engine::SkrRecycle;
    skr_cfg.out_dir = None;
    skr_cfg.trace_out = base.trace_out.as_ref().map(|p| engine_trace_path(p, "skr"));
    let skr = Pipeline::new(skr_cfg).run()?.metrics;
    Ok((gm, skr))
}

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let cfg = PipelineConfig::from_args(args)?;
    let (gm, skr) = run_pair(&cfg)?;
    let sp: Speedup = speedup(&gm, &skr);
    println!(
        "config: family={} n={} count={} precond={} tol={:.0e} m={} k={}",
        cfg.family.label(),
        cfg.unknowns,
        cfg.count,
        cfg.precond.label(),
        cfg.solver.tol,
        cfg.solver.m,
        cfg.solver.k
    );
    println!(
        "GMRES : mean {:.4}s  {:.1} iters/sys  ({} max-iter hits)",
        gm.mean_time(),
        gm.mean_iters(),
        gm.max_iter_hits
    );
    println!(
        "SKR   : mean {:.4}s  {:.1} iters/sys  ({} max-iter hits)",
        skr.mean_time(),
        skr.mean_iters(),
        skr.max_iter_hits
    );
    println!("speedup (GMRES/SKR): time {:.2}x  iters {:.2}x", sp.time, sp.iters);
    if let Some(trace) = &cfg.trace_out {
        println!(
            "traces: {}  {}",
            engine_trace_path(trace, "gmres").display(),
            engine_trace_path(trace, "skr").display()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_engine_trace_paths_do_not_collide() {
        let p = std::path::Path::new("results/t.jsonl");
        assert_eq!(engine_trace_path(p, "gmres"), std::path::Path::new("results/t.gmres.jsonl"));
        assert_eq!(engine_trace_path(p, "skr"), std::path::Path::new("results/t.skr.jsonl"));
        let bare = std::path::Path::new("trace");
        assert_eq!(engine_trace_path(bare, "skr"), std::path::Path::new("trace.skr"));
    }
}
