//! `skr compare` — run the same configuration under GMRES and SKR and print
//! the speedup pair; the smallest useful readout and the building block the
//! table harnesses loop over.

use super::{speedup, Speedup};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::{Pipeline, PipelineConfig, SortStrategy};
use crate::solver::Engine;
use crate::util::args::Args;
use anyhow::Result;

/// Run one configuration under both engines; returns (gmres, skr) metrics.
pub fn run_pair(base: &PipelineConfig) -> Result<(RunMetrics, RunMetrics)> {
    let mut gm_cfg = base.clone();
    gm_cfg.engine = Engine::Gmres;
    gm_cfg.sort = SortStrategy::None; // the baseline solves in stream order
    gm_cfg.out_dir = None;
    let gm = Pipeline::new(gm_cfg).run()?.metrics;

    let mut skr_cfg = base.clone();
    skr_cfg.engine = Engine::SkrRecycle;
    skr_cfg.out_dir = None;
    let skr = Pipeline::new(skr_cfg).run()?.metrics;
    Ok((gm, skr))
}

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let cfg = PipelineConfig::from_args(args)?;
    let (gm, skr) = run_pair(&cfg)?;
    let sp: Speedup = speedup(&gm, &skr);
    println!(
        "config: family={} n={} count={} precond={} tol={:.0e} m={} k={}",
        cfg.family.label(),
        cfg.unknowns,
        cfg.count,
        cfg.precond.label(),
        cfg.solver.tol,
        cfg.solver.m,
        cfg.solver.k
    );
    println!(
        "GMRES : mean {:.4}s  {:.1} iters/sys  ({} max-iter hits)",
        gm.mean_time(),
        gm.mean_iters(),
        gm.max_iter_hits
    );
    println!(
        "SKR   : mean {:.4}s  {:.1} iters/sys  ({} max-iter hits)",
        skr.mean_time(),
        skr.mean_iters(),
        skr.max_iter_hits
    );
    println!("speedup (GMRES/SKR): time {:.2}x  iters {:.2}x", sp.time, sp.iters);
    Ok(())
}
