//! Experiment harnesses — one module per paper artifact. Shared between the
//! `skr` CLI subcommands and the `cargo bench` targets so every table and
//! figure can be regenerated from either entry point. Each harness prints
//! paper-style rows and mirrors them to CSV under `results/`.

pub mod ablation;
pub mod compare;
pub mod figures;
pub mod parallel;
pub mod sweeps;
pub mod table1;
pub mod train;
pub mod validate;

use crate::coordinator::metrics::RunMetrics;

/// A (time speedup, iteration speedup) pair — the paper's table cell.
#[derive(Debug, Clone, Copy)]
pub struct Speedup {
    pub time: f64,
    pub iters: f64,
}

/// Compute GMRES/SKR ratios (>1 ⇒ SKR wins) from two aggregates.
pub fn speedup(gmres: &RunMetrics, skr: &RunMetrics) -> Speedup {
    let ratio = |a: f64, b: f64| if b > 0.0 { a / b } else { f64::NAN };
    Speedup {
        time: ratio(gmres.mean_time(), skr.mean_time()),
        iters: ratio(gmres.mean_iters(), skr.mean_iters()),
    }
}

/// Results directory (created on demand).
pub fn results_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_ratios() {
        let mut g = RunMetrics::default();
        g.systems = 2;
        g.solve_seconds = 4.0;
        g.total_iters = 200;
        let mut s = RunMetrics::default();
        s.systems = 2;
        s.solve_seconds = 1.0;
        s.total_iters = 20;
        let sp = speedup(&g, &s);
        assert!((sp.time - 4.0).abs() < 1e-12);
        assert!((sp.iters - 10.0).abs() < 1e-12);
    }
}
