//! Paper **Table 1** — the headline result: SKR vs GMRES computation-time
//! and iteration speedups across the four datasets, seven preconditioners
//! and three tolerances per dataset.
//!
//! Cells are printed as `time×/iters×` exactly like the paper. Default
//! sizes are reduced so the sweep completes in CI time; `--full` runs the
//! paper's matrix sizes (2500–71313 unknowns).

use super::compare::run_pair;
use super::results_dir;
use crate::coordinator::PipelineConfig;
use crate::pde::FamilyKind;
use crate::precond::PrecondKind;
use crate::util::args::Args;
use crate::util::table::{ratio_cell, Table};
use anyhow::Result;

/// Per-family scales and tolerance triples (paper Table 1 rows).
pub fn family_plan(full: bool) -> Vec<(FamilyKind, usize, [f64; 3])> {
    if full {
        vec![
            (FamilyKind::Darcy, 6400, [1e-2, 1e-5, 1e-8]),
            (FamilyKind::Thermal, 11063, [1e-5, 1e-8, 1e-11]),
            (FamilyKind::Poisson, 71313, [1e-5, 1e-8, 1e-11]),
            (FamilyKind::Helmholtz, 10000, [1e-2, 1e-5, 1e-7]),
        ]
    } else {
        vec![
            (FamilyKind::Darcy, 1600, [1e-2, 1e-5, 1e-8]),
            (FamilyKind::Thermal, 1600, [1e-5, 1e-8, 1e-11]),
            (FamilyKind::Poisson, 2500, [1e-5, 1e-8, 1e-11]),
            (FamilyKind::Helmholtz, 1600, [1e-2, 1e-5, 1e-7]),
        ]
    }
}

/// Run the Table-1 grid; returns the rendered table for logging.
pub fn run_with(count: usize, full: bool, preconds: &[PrecondKind], seed: u64) -> Result<Table> {
    let mut header: Vec<&str> = vec!["Dataset", "tol"];
    let labels: Vec<String> = preconds.iter().map(|p| p.label().to_string()).collect();
    header.extend(labels.iter().map(|s| s.as_str()));
    let mut table = Table::new(
        "Table 1 — GMRES/SKR speedup: time x / iters x (>1 means SKR wins)",
        &header,
    );

    for (family, unknowns, tols) in family_plan(full) {
        for (ti, &tol) in tols.iter().enumerate() {
            let mut row = vec![
                if ti == 0 { format!("{} ({unknowns})", family.label()) } else { String::new() },
                format!("{tol:.0e}"),
            ];
            for &precond in preconds {
                let mut cfg = PipelineConfig::default();
                cfg.family = family;
                cfg.unknowns = unknowns;
                cfg.count = count;
                cfg.precond = precond;
                cfg.solver.tol = tol;
                cfg.seed = seed;
                cfg.threads = 1;
                let (gm, skr) = run_pair(&cfg)?;
                let sp = super::speedup(&gm, &skr);
                row.push(ratio_cell(sp.time, sp.iters));
                eprintln!(
                    "  [{} n={} tol={tol:.0e} {}] GMRES {:.4}s/{:.0}it  SKR {:.4}s/{:.0}it  => {}",
                    family.label(),
                    unknowns,
                    precond.label(),
                    gm.mean_time(),
                    gm.mean_iters(),
                    skr.mean_time(),
                    skr.mean_iters(),
                    ratio_cell(sp.time, sp.iters),
                );
            }
            table.row(row);
        }
    }
    Ok(table)
}

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let full = args.flag("full");
    let count = args.num_or("count", if full { 100 } else { 10 });
    let preconds: Vec<PrecondKind> = if args.flag("quick") {
        vec![PrecondKind::None, PrecondKind::Jacobi, PrecondKind::Ilu]
    } else {
        PrecondKind::ALL.to_vec()
    };
    let table = run_with(count, full, &preconds, args.num_or("seed", 0u64))?;
    print!("{}", table.render());
    table.write_csv(&results_dir().join("table1.csv"))?;
    println!("\nCSV → results/table1.csv");
    Ok(())
}
