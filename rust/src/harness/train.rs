//! `skr train` — train the AOT-compiled FNO on a generated dataset through
//! the PJRT runtime, logging the loss curve (the "NO consumes the data the
//! pipeline produced" leg of the system).

use crate::no::{FnoDataset, Trainer};
use crate::runtime::{FnoRuntime, Manifest};
use crate::util::args::Args;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let data_dir = PathBuf::from(
        args.get("data").context("--data DIR required (a `skr generate --out DIR` export)")?,
    );
    let art_dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let steps = args.num_or("steps", 300usize);

    let mut fno = FnoRuntime::load(&art_dir)?;
    println!(
        "FNO loaded: grid={} batch={} width={} modes={} layers={} ({} weights)",
        fno.manifest.grid,
        fno.manifest.batch,
        fno.manifest.width,
        fno.manifest.modes,
        fno.manifest.layers,
        fno.manifest.num_weights()
    );
    let ds = FnoDataset::load(&data_dir, fno.manifest.grid, 0.2, args.num_or("seed", 0u64))?;
    println!(
        "dataset: {} samples ({} train / {} test), grid {}",
        ds.count,
        ds.train_idx.len(),
        ds.test_idx.len(),
        ds.grid
    );

    let trainer = Trainer { steps, eval_every: (steps / 6).max(1), seed: 1, log: true };
    let report = trainer.train(&mut fno, &ds)?;
    println!(
        "trained {} steps in {:.1}s — final test rel-L2 {:.4}",
        report.steps, report.seconds, report.final_test_rel_l2
    );

    // Mirror the loss curve to CSV for plotting.
    let mut t = crate::util::table::Table::new("loss curve", &["step", "train_loss"]);
    for (s, l) in &report.losses {
        t.row(vec![s.to_string(), format!("{l:.6}")]);
    }
    let csv = super::results_dir().join("train_loss_curve.csv");
    t.write_csv(&csv)?;
    println!("loss curve → {}", csv.display());
    Ok(())
}
