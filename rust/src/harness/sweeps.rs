//! Paper **Tables 3–30** — the full per-family sweep: for each dataset and
//! preconditioner, a size × tolerance grid reporting mean time and mean
//! iterations for both engines (the paper's detailed appendix tables).

use super::compare::run_pair;
use super::results_dir;
use crate::coordinator::PipelineConfig;
use crate::pde::FamilyKind;
use crate::precond::PrecondKind;
use crate::util::args::Args;
use crate::util::table::Table;
use anyhow::Result;

/// Sweep grid per family (sizes, tolerances).
pub fn sweep_plan(family: FamilyKind, full: bool) -> (Vec<usize>, Vec<f64>) {
    match (family, full) {
        (FamilyKind::Darcy, true) => {
            (vec![2500, 6400, 10000, 22500, 40000], vec![1e-1, 1e-2, 1e-4, 1e-6, 1e-8])
        }
        (FamilyKind::Darcy, false) => (vec![900, 1600], vec![1e-2, 1e-5, 1e-8]),
        (FamilyKind::Thermal, true) => {
            (vec![2755, 7821, 11063, 17593, 31157], vec![1e-5, 1e-7, 1e-9, 1e-11])
        }
        (FamilyKind::Thermal, false) => (vec![900, 1600], vec![1e-5, 1e-8, 1e-11]),
        (FamilyKind::Poisson, true) => {
            (vec![7153, 11237, 20245, 45337, 71313], vec![1e-5, 1e-7, 1e-9, 1e-11])
        }
        (FamilyKind::Poisson, false) => (vec![1600, 2500], vec![1e-5, 1e-8, 1e-11]),
        (FamilyKind::Helmholtz, true) => {
            (vec![2500, 6400, 10000, 22500], vec![1e-1, 1e-2, 1e-4, 1e-6, 1e-7])
        }
        (FamilyKind::Helmholtz, false) => (vec![900, 1600], vec![1e-2, 1e-5, 1e-7]),
    }
}

/// Run the sweep for one family × preconditioner; returns the paper-style
/// table (time block then iter block).
pub fn sweep_table(
    family: FamilyKind,
    precond: PrecondKind,
    count: usize,
    full: bool,
    seed: u64,
) -> Result<Table> {
    let (sizes, tols) = sweep_plan(family, full);
    let mut header: Vec<String> = vec!["metric".into(), "n".into(), "solver".into()];
    header.extend(tols.iter().map(|t| format!("{t:.0e}")));
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!("{} / {} — GMRES vs SKR (mean per-system)", family.label(), precond.label()),
        &hdr_refs,
    );

    // metric → n → (gmres cells, skr cells)
    let mut time_rows = Vec::new();
    let mut iter_rows = Vec::new();
    for &n in &sizes {
        let mut gm_t = Vec::new();
        let mut skr_t = Vec::new();
        let mut gm_i = Vec::new();
        let mut skr_i = Vec::new();
        for &tol in &tols {
            let mut cfg = PipelineConfig::default();
            cfg.family = family;
            cfg.unknowns = n;
            cfg.count = count;
            cfg.precond = precond;
            cfg.solver.tol = tol;
            cfg.threads = 1;
            cfg.seed = seed;
            let (gm, skr) = run_pair(&cfg)?;
            gm_t.push(format!("{:.4}", gm.mean_time()));
            skr_t.push(format!("{:.4}", skr.mean_time()));
            gm_i.push(format!("{:.0}", gm.mean_iters()));
            skr_i.push(format!("{:.0}", skr.mean_iters()));
            eprintln!(
                "  [{} {} n={n} tol={tol:.0e}] GMRES {:.4}s/{:.0}  SKR {:.4}s/{:.0}",
                family.label(),
                precond.label(),
                gm.mean_time(),
                gm.mean_iters(),
                skr.mean_time(),
                skr.mean_iters()
            );
        }
        time_rows.push((n, gm_t, skr_t));
        iter_rows.push((n, gm_i, skr_i));
    }
    for (n, gm, skr) in time_rows {
        let mut r1 = vec!["time".to_string(), n.to_string(), "GMRES".to_string()];
        r1.extend(gm);
        table.row(r1);
        let mut r2 = vec![String::new(), String::new(), "SKR".to_string()];
        r2.extend(skr);
        table.row(r2);
    }
    for (n, gm, skr) in iter_rows {
        let mut r1 = vec!["iter".to_string(), n.to_string(), "GMRES".to_string()];
        r1.extend(gm);
        table.row(r1);
        let mut r2 = vec![String::new(), String::new(), "SKR".to_string()];
        r2.extend(skr);
        table.row(r2);
    }
    Ok(table)
}

/// CLI entry: `skr tables [--family F] [--precond P] [--full]`.
pub fn run(args: &Args) -> Result<()> {
    let full = args.flag("full");
    let count = args.num_or("count", if full { 50 } else { 8 });
    let families: Vec<FamilyKind> = match args.get("family") {
        Some(f) => vec![FamilyKind::parse(f)?],
        None => FamilyKind::ALL.to_vec(),
    };
    let preconds: Vec<PrecondKind> = match args.get("precond") {
        Some(p) => vec![PrecondKind::parse(p)?],
        None if full => PrecondKind::ALL.to_vec(),
        None => vec![PrecondKind::None, PrecondKind::Jacobi, PrecondKind::Ilu],
    };
    for family in families {
        for &precond in &preconds {
            let t = sweep_table(family, precond, count, full, args.num_or("seed", 0u64))?;
            print!("{}", t.render());
            println!();
            let name = format!(
                "sweep_{}_{}.csv",
                family.label().to_lowercase(),
                precond.label().to_lowercase()
            );
            t.write_csv(&results_dir().join(name))?;
        }
    }
    println!("CSVs → results/sweep_*.csv");
    Ok(())
}
