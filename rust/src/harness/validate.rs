//! `skr validate` — paper Table 33 (dataset-validity): generate the same
//! Darcy dataset twice, once solved by GMRES and once by SKR, train the
//! same FNO on each, and show the training dynamics coincide — i.e. the
//! accelerated pipeline changes nothing for the downstream neural operator.

use crate::coordinator::{Pipeline, PipelineConfig, SortStrategy};
use crate::no::{FnoDataset, Trainer};
use crate::runtime::{FnoRuntime, Manifest};
use crate::solver::Engine;
use crate::util::args::Args;
use crate::util::table::Table;
use anyhow::Result;

/// Table-33 analogue outcome, returned for tests/benches.
#[derive(Debug, Clone)]
pub struct ValidityReport {
    /// (label, test-error curve at eval points).
    pub curves: Vec<(String, Vec<(usize, f64)>)>,
    pub final_errors: Vec<(String, f64)>,
}

/// Run the experiment at a given scale.
pub fn run_experiment(
    count: usize,
    unknowns: usize,
    steps: usize,
    seed: u64,
) -> Result<ValidityReport> {
    let art_dir = Manifest::default_dir();
    let mut curves = Vec::new();
    let mut final_errors = Vec::new();

    for (label, engine) in [("GMRES", Engine::Gmres), ("SKR", Engine::SkrRecycle)] {
        let dir = std::env::temp_dir().join(format!("skr_validate_{}", label.to_lowercase()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = PipelineConfig::default();
        cfg.unknowns = unknowns;
        cfg.count = count;
        cfg.engine = engine;
        cfg.sort = if engine == Engine::SkrRecycle { SortStrategy::Greedy } else { SortStrategy::None };
        cfg.solver.tol = 1e-8;
        cfg.threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
        cfg.seed = seed;
        cfg.out_dir = Some(dir.clone());
        let r = Pipeline::new(cfg).run()?;
        println!(
            "{label}: generated {count} systems, mean {:.1} iters, {:.2}s solve",
            r.metrics.mean_iters(),
            r.metrics.solve_seconds
        );

        // Both runs must train the *same* model from the same init.
        let mut fno = FnoRuntime::load(&art_dir)?;
        let ds = FnoDataset::load(&dir, fno.manifest.grid, 0.2, 7)?;
        let trainer = Trainer { steps, eval_every: (steps / 5).max(1), seed: 11, log: false };
        let report = trainer.train(&mut fno, &ds)?;
        println!("{label}: final test rel-L2 {:.4}", report.final_test_rel_l2);
        curves.push((label.to_string(), report.test_curve.clone()));
        final_errors.push((label.to_string(), report.final_test_rel_l2));
    }
    Ok(ValidityReport { curves, final_errors })
}

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let full = args.flag("full");
    let count = args.num_or("count", if full { 1024 } else { 96 });
    let unknowns = args.num_or("n", if full { 2500 } else { 1024 });
    let steps = args.num_or("steps", if full { 500 } else { 150 });
    let rep = run_experiment(count, unknowns, steps, args.num_or("seed", 0u64))?;

    let mut t = Table::new(
        "Table 33 — FNO test rel-L2 when trained on GMRES- vs SKR-generated data",
        &["engine", "eval@", "rel-L2"],
    );
    for (label, curve) in &rep.curves {
        for (step, err) in curve {
            t.row(vec![label.clone(), step.to_string(), format!("{err:.4}")]);
        }
    }
    print!("{}", t.render());
    t.write_csv(&super::results_dir().join("table33_validity.csv"))?;

    let (g, s) = (rep.final_errors[0].1, rep.final_errors[1].1);
    let gap = (g - s).abs() / g.max(s).max(1e-12);
    println!("\nfinal errors: GMRES {g:.4} vs SKR {s:.4} (relative gap {:.1}%)", gap * 100.0);
    if gap < 0.15 {
        println!("=> datasets are training-equivalent (paper Table 33 conclusion holds)");
    } else {
        println!("=> WARNING: gap exceeds 15% — inspect the runs");
    }
    Ok(())
}
