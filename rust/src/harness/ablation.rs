//! Paper **Table 2** — the sorting ablation: SKR with and without the
//! sorting stage (plus a random-shuffle adversarial arm) on Darcy flow,
//! reporting time, iterations and the mean δ-subspace distance between
//! consecutive recycle spaces.
//!
//! The paper's configuration is Darcy + SOR at n = 10⁴ with thousands of
//! samples; at that sampling density the greedy sort finds genuinely close
//! parameter neighbours. At CI scale (a few hundred samples) a raw
//! two-phase medium leaves all neighbours nearly equidistant, so the
//! default arms use the smooth lognormal medium (continuous in the GRF
//! parameters, effective parameter dimension ≈ 10) where the sort's δ
//! reduction is measurable at small count — pass `--full` for the paper's
//! own configuration.

use super::results_dir;
use crate::coordinator::{Pipeline, PipelineConfig, SortStrategy};
use crate::pde::darcy::{DarcyFamily, KMap};
use crate::pde::FamilyKind;
use crate::precond::PrecondKind;
use crate::util::args::Args;
use crate::util::table::Table;
use anyhow::Result;

/// One ablation row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub mean_time: f64,
    pub mean_iters: f64,
    pub mean_delta: f64,
    /// Mean principal-angle δ (discriminative; the spectral δ saturates
    /// near 1 for k-dimensional recycle spaces).
    pub mean_delta_angles: f64,
}

/// Ablation experiment parameters.
#[derive(Debug, Clone, Copy)]
pub struct AblationSpec {
    pub unknowns: usize,
    pub count: usize,
    pub tol: f64,
    pub seed: u64,
    pub precond: PrecondKind,
    /// `None` ⇒ the Darcy default two-phase medium (paper configuration);
    /// `Some(σ)` ⇒ smooth lognormal exp(σ·GRF) medium.
    pub lognormal_sigma: Option<f64>,
    /// GRF smoothness exponent.
    pub grf_alpha: f64,
}

/// Run the three ablation arms.
pub fn run_experiment(spec: AblationSpec) -> Result<Vec<AblationRow>> {
    let arms = [
        ("SKR(sort)", SortStrategy::Greedy),
        ("SKR(nosort)", SortStrategy::None),
        ("SKR(shuffle)", SortStrategy::Shuffle),
    ];
    let mut rows = Vec::new();
    for (label, sort) in arms {
        let mut cfg = PipelineConfig::default();
        cfg.family = FamilyKind::Darcy;
        cfg.unknowns = spec.unknowns;
        cfg.count = spec.count;
        cfg.precond = spec.precond;
        cfg.solver.tol = spec.tol;
        cfg.sort = sort;
        cfg.threads = 1;
        cfg.seed = spec.seed;
        cfg.instrument_delta = true;
        let mut fam = DarcyFamily::with_unknowns(spec.unknowns);
        fam.grf.alpha = spec.grf_alpha;
        if let Some(sigma) = spec.lognormal_sigma {
            fam.kmap = KMap::LogNormal(sigma);
        }
        let r = Pipeline::with_family(cfg, Box::new(fam)).run()?;
        rows.push(AblationRow {
            label: label.to_string(),
            mean_time: r.metrics.mean_time(),
            mean_iters: r.metrics.mean_iters(),
            mean_delta: r.delta.mean(),
            mean_delta_angles: r.delta.mean_of_means(),
        });
    }
    Ok(rows)
}

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let full = args.flag("full");
    let spec = AblationSpec {
        unknowns: args.num_or("n", if full { 10_000 } else { 900 }),
        count: args.num_or("count", if full { 300 } else { 150 }),
        tol: args.num_or("tol", 1e-8f64),
        seed: args.num_or("seed", 5u64),
        // Paper configuration under --full; sensitized smooth medium at CI
        // scale (see module docs).
        precond: if full { PrecondKind::Sor } else { PrecondKind::Jacobi },
        lognormal_sigma: if full { None } else { Some(2.0) },
        grf_alpha: if full { 2.0 } else { 5.0 },
    };
    let rows = run_experiment(spec)?;

    let mut t = Table::new(
        &format!(
            "Table 2 — sort ablation (Darcy, {:?}, n={}, tol={:.0e})",
            spec.precond, spec.unknowns, spec.tol
        ),
        &["arm", "Time(s)", "Iter", "delta(spec)", "delta(mean)"],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            format!("{:.4}", r.mean_time),
            format!("{:.1}", r.mean_iters),
            format!("{:.3}", r.mean_delta),
            format!("{:.3}", r.mean_delta_angles),
        ]);
    }
    print!("{}", t.render());
    t.write_csv(&results_dir().join("table2_ablation.csv"))?;

    let (s, ns) = (&rows[0], &rows[1]);
    println!(
        "\nsort vs nosort: time −{:.1}%, iters −{:.1}%, delta(mean-angle) {:.3} → {:.3}",
        (1.0 - s.mean_time / ns.mean_time) * 100.0,
        (1.0 - s.mean_iters / ns.mean_iters) * 100.0,
        ns.mean_delta_angles,
        s.mean_delta_angles
    );
    Ok(())
}
