//! Paper **Tables 31/32** — the parallel strategies of Appendix E.2:
//!
//! * Table 31: both engines sharded over T worker threads (the MPI-rank
//!   analogue); SKR sorts globally, then each worker recycles within its
//!   contiguous batch.
//! * Table 32: the "block" variant — here reproduced as SKR with
//!   block-structured preconditioning (BJacobi) across T threads against a
//!   sequential GMRES baseline, documenting the substitution (the paper's
//!   block-MPI matrix distribution is a memory-layout strategy our
//!   shared-memory testbed does not need; see DESIGN.md §Substitutions).

use super::results_dir;
use crate::coordinator::{Pipeline, PipelineConfig, SortStrategy};
use crate::pde::FamilyKind;
use crate::precond::PrecondKind;
use crate::solver::Engine;
use crate::util::args::Args;
use crate::util::table::Table;
use anyhow::Result;

/// CLI entry.
pub fn run(args: &Args) -> Result<()> {
    let full = args.flag("full");
    let n = args.num_or("n", if full { 10_000 } else { 1600 });
    let threads = args.num_or(
        "threads",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
    );
    let per_thread = args.num_or("per-thread", if full { 100 } else { 8 });
    let count = threads * per_thread;
    let tols = [1e-3, 1e-5, 1e-7];

    // ---- Table 31: parallel SKR vs parallel GMRES ------------------------
    let mut t31 = Table::new(
        &format!("Table 31 — parallel ({threads} threads), Helmholtz n={n}, SOR, {count} systems"),
        &["metric", "engine", "1e-3", "1e-5", "1e-7"],
    );
    let mut rows: Vec<Vec<String>> = vec![
        vec!["time(s)".into(), "Parallel GMRES".into()],
        vec!["time(s)".into(), "Parallel SKR".into()],
        vec!["iter".into(), "Parallel GMRES".into()],
        vec!["iter".into(), "Parallel SKR".into()],
    ];
    for &tol in &tols {
        for (row_t, row_i, engine) in [(0usize, 2usize, Engine::Gmres), (1, 3, Engine::SkrRecycle)] {
            let mut cfg = PipelineConfig::default();
            cfg.family = FamilyKind::Helmholtz;
            cfg.unknowns = n;
            cfg.count = count;
            cfg.precond = PrecondKind::Sor;
            cfg.engine = engine;
            cfg.sort =
                if engine == Engine::SkrRecycle { SortStrategy::Greedy } else { SortStrategy::None };
            cfg.solver.tol = tol;
            cfg.threads = threads;
            let r = Pipeline::new(cfg).run()?;
            // Report wall-clock per system over the parallel run (the paper
            // averages across threads) and mean iterations.
            rows[row_t].push(format!("{:.4}", r.metrics.wall_seconds / count as f64));
            rows[row_i].push(format!("{:.0}", r.metrics.mean_iters()));
            eprintln!(
                "  [t31 tol={tol:.0e} {}] wall/system {:.4}s, {:.0} iters",
                engine.label(),
                r.metrics.wall_seconds / count as f64,
                r.metrics.mean_iters()
            );
        }
    }
    for r in rows {
        t31.row(r);
    }
    print!("{}", t31.render());
    t31.write_csv(&results_dir().join("table31_parallel.csv"))?;

    // ---- Table 32: block variant -----------------------------------------
    let mut t32 = Table::new(
        &format!("Table 32 — block variant, Helmholtz n={n}, {count} systems"),
        &["metric", "engine", "1e-3", "1e-5", "1e-7"],
    );
    let mut rows: Vec<Vec<String>> = vec![
        vec!["time(s)".into(), "GMRES (seq)".into()],
        vec!["time(s)".into(), "Block SKR".into()],
        vec!["iter".into(), "GMRES (seq)".into()],
        vec!["iter".into(), "Block SKR".into()],
    ];
    for &tol in &tols {
        // Sequential GMRES baseline (the paper's Table-32 comparator).
        let mut cfg = PipelineConfig::default();
        cfg.family = FamilyKind::Helmholtz;
        cfg.unknowns = n;
        cfg.count = count / threads.max(1); // scale the baseline workload
        cfg.precond = PrecondKind::Sor;
        cfg.engine = Engine::Gmres;
        cfg.sort = SortStrategy::None;
        cfg.solver.tol = tol;
        cfg.threads = 1;
        let g = Pipeline::new(cfg).run()?;
        rows[0].push(format!("{:.4}", g.metrics.mean_time()));
        rows[2].push(format!("{:.0}", g.metrics.mean_iters()));

        // Block SKR: block preconditioner + threaded batches.
        let mut cfg = PipelineConfig::default();
        cfg.family = FamilyKind::Helmholtz;
        cfg.unknowns = n;
        cfg.count = count;
        cfg.precond = PrecondKind::BJacobi;
        cfg.engine = Engine::SkrRecycle;
        cfg.sort = SortStrategy::Greedy;
        cfg.solver.tol = tol;
        cfg.threads = threads;
        let s = Pipeline::new(cfg).run()?;
        rows[1].push(format!("{:.4}", s.metrics.wall_seconds / count as f64));
        rows[3].push(format!("{:.0}", s.metrics.mean_iters()));
        eprintln!(
            "  [t32 tol={tol:.0e}] GMRES(seq) {:.4}s/{:.0}  BlockSKR {:.4}s/{:.0}",
            g.metrics.mean_time(),
            g.metrics.mean_iters(),
            s.metrics.wall_seconds / count as f64,
            s.metrics.mean_iters()
        );
    }
    for r in rows {
        t32.row(r);
    }
    print!("{}", t32.render());
    t32.write_csv(&results_dir().join("table32_block.csv"))?;
    println!("\nCSVs → results/table31_parallel.csv, results/table32_block.csv");
    Ok(())
}
