//! FNO training loop over the PJRT runtime: mini-batch Adam on a generated
//! dataset, periodic test-set evaluation, loss-curve logging — the engine of
//! the Table-33 dataset-validity experiment and the end-to-end example.

use super::data::FnoDataset;
use crate::runtime::FnoRuntime;
use crate::util::prng::Rng;
use crate::util::timer::Timer;
use anyhow::Result;

/// Training outcome.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, train loss) samples.
    pub losses: Vec<(usize, f64)>,
    /// (step, test relative L2) evaluations.
    pub test_curve: Vec<(usize, f64)>,
    pub final_test_rel_l2: f64,
    pub steps: usize,
    pub seconds: f64,
}

/// Configurable trainer.
pub struct Trainer {
    pub steps: usize,
    pub eval_every: usize,
    pub seed: u64,
    pub log: bool,
}

impl Default for Trainer {
    fn default() -> Self {
        Trainer { steps: 300, eval_every: 50, seed: 0, log: false }
    }
}

impl Trainer {
    /// Train `fno` on `ds`; both must share the same grid side.
    pub fn train(&self, fno: &mut FnoRuntime, ds: &FnoDataset) -> Result<TrainReport> {
        anyhow::ensure!(
            fno.manifest.grid == ds.grid,
            "model grid {} != dataset grid {}",
            fno.manifest.grid,
            ds.grid
        );
        let b = fno.manifest.batch;
        anyhow::ensure!(ds.train_idx.len() >= b, "dataset smaller than one batch");
        let timer = Timer::start();
        let mut rng = Rng::new(self.seed);
        let mut losses = Vec::new();
        let mut test_curve = Vec::new();

        for step in 0..self.steps {
            // Sample a batch without replacement within the epoch position.
            let ids: Vec<usize> =
                (0..b).map(|_| ds.train_idx[rng.below(ds.train_idx.len())]).collect();
            let (x, y) = ds.batch(&ids);
            let loss = fno.train_step(&x, &y)? as f64;
            losses.push((step, loss));
            if self.log && step % 20 == 0 {
                eprintln!("step {step:4}  loss {loss:.4}");
            }
            if (step + 1) % self.eval_every == 0 || step + 1 == self.steps {
                let err = self.evaluate(fno, ds)?;
                test_curve.push((step + 1, err));
                if self.log {
                    eprintln!("step {:4}  test rel-L2 {err:.4}", step + 1);
                }
            }
        }
        let final_test_rel_l2 = test_curve.last().map(|&(_, e)| e).unwrap_or(f64::NAN);
        Ok(TrainReport {
            losses,
            test_curve,
            final_test_rel_l2,
            steps: self.steps,
            seconds: timer.secs(),
        })
    }

    /// Mean relative L2 over the test split (full batches only).
    pub fn evaluate(&self, fno: &FnoRuntime, ds: &FnoDataset) -> Result<f64> {
        let b = fno.manifest.batch;
        let mut total = 0.0;
        let mut batches = 0;
        for chunk in ds.test_idx.chunks(b) {
            if chunk.len() < b {
                break; // fixed-shape AOT module: skip the ragged tail
            }
            let (x, _) = ds.batch(chunk);
            let preds = fno.predict(&x)?;
            total += ds.relative_l2(chunk, &preds);
            batches += 1;
        }
        anyhow::ensure!(batches > 0, "test split smaller than one batch");
        Ok(total / batches as f64)
    }
}
