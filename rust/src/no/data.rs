//! Dataset → FNO tensor conversion: load the pipeline's `.npy` export,
//! bilinearly upsample input fields to the model grid, normalize, and
//! produce train/test batches in the `[B, S, S, 1]` layout the AOT module
//! expects.

use crate::coordinator::dataset;
use crate::util::prng::Rng;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A dataset resampled to the FNO grid, normalized, split and batchable.
#[derive(Debug, Clone)]
pub struct FnoDataset {
    /// Model grid side S.
    pub grid: usize,
    /// Inputs `[count, S, S]` flattened, standardized.
    pub inputs: Vec<f32>,
    /// Targets `[count, S, S]` flattened, scaled by `target_scale`.
    pub targets: Vec<f32>,
    pub count: usize,
    /// Multiply model outputs by this to recover solution units.
    pub target_scale: f32,
    /// Index split.
    pub train_idx: Vec<usize>,
    pub test_idx: Vec<usize>,
}

impl FnoDataset {
    /// Load from a pipeline export, resampling fields to `grid`.
    pub fn load(dir: &Path, grid: usize, test_fraction: f64, seed: u64) -> Result<FnoDataset> {
        let (ins, sols, _meta) = dataset::load(dir).context("loading dataset")?;
        let count = ins.shape[0];
        if sols.shape[0] != count {
            bail!("inputs/solutions count mismatch");
        }
        let in_side = int_sqrt(ins.shape[1])
            .with_context(|| format!("input dim {} is not a square grid", ins.shape[1]))?;
        let sol_side = int_sqrt(sols.shape[1])
            .with_context(|| format!("solution dim {} is not a square grid", sols.shape[1]))?;

        // Resample both to the model grid.
        let mut inputs = Vec::with_capacity(count * grid * grid);
        let mut targets = Vec::with_capacity(count * grid * grid);
        for i in 0..count {
            let a = &ins.data[i * in_side * in_side..(i + 1) * in_side * in_side];
            let b = &sols.data[i * sol_side * sol_side..(i + 1) * sol_side * sol_side];
            inputs.extend(bilinear(a, in_side, grid).into_iter().map(|v| v as f32));
            targets.extend(bilinear(b, sol_side, grid).into_iter().map(|v| v as f32));
        }

        // Standardize inputs, scale targets to ~unit std.
        standardize(&mut inputs);
        let tstd = std_of(&targets).max(1e-12);
        for t in targets.iter_mut() {
            *t /= tstd;
        }

        // Split.
        let mut rng = Rng::new(seed);
        let mut idx = rng.permutation(count);
        let ntest = ((count as f64) * test_fraction).round() as usize;
        let test_idx = idx.split_off(count - ntest.min(count));
        Ok(FnoDataset {
            grid,
            inputs,
            targets,
            count,
            target_scale: tstd,
            train_idx: idx,
            test_idx,
        })
    }

    /// Assemble batch tensors `[B, S, S, 1]` for the given sample indices.
    pub fn batch(&self, ids: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let gg = self.grid * self.grid;
        let mut x = Vec::with_capacity(ids.len() * gg);
        let mut y = Vec::with_capacity(ids.len() * gg);
        for &i in ids {
            x.extend_from_slice(&self.inputs[i * gg..(i + 1) * gg]);
            y.extend_from_slice(&self.targets[i * gg..(i + 1) * gg]);
        }
        (x, y)
    }

    /// Mean relative L2 between predictions and targets for `ids`
    /// (scale-invariant, so usable directly on normalized units).
    pub fn relative_l2(&self, ids: &[usize], preds: &[f32]) -> f64 {
        let gg = self.grid * self.grid;
        let mut total = 0.0;
        for (bi, &i) in ids.iter().enumerate() {
            let t = &self.targets[i * gg..(i + 1) * gg];
            let p = &preds[bi * gg..(bi + 1) * gg];
            let mut d2 = 0.0f64;
            let mut n2 = 0.0f64;
            for (a, b) in p.iter().zip(t) {
                d2 += (*a as f64 - *b as f64).powi(2);
                n2 += (*b as f64).powi(2);
            }
            total += (d2.sqrt()) / (n2.sqrt() + 1e-8);
        }
        total / ids.len().max(1) as f64
    }
}

fn int_sqrt(n: usize) -> Option<usize> {
    let s = (n as f64).sqrt().round() as usize;
    (s * s == n).then_some(s)
}

/// Bilinear resample a row-major `src`-side square field to `dst` side.
pub fn bilinear(field: &[f64], src: usize, dst: usize) -> Vec<f64> {
    assert_eq!(field.len(), src * src);
    if src == dst {
        return field.to_vec();
    }
    let mut out = Vec::with_capacity(dst * dst);
    let scale = (src.max(1) - 1) as f64 / (dst.max(2) - 1) as f64;
    for r in 0..dst {
        let fr = r as f64 * scale;
        let r0 = fr.floor() as usize;
        let r1 = (r0 + 1).min(src - 1);
        let wr = fr - r0 as f64;
        for c in 0..dst {
            let fc = c as f64 * scale;
            let c0 = fc.floor() as usize;
            let c1 = (c0 + 1).min(src - 1);
            let wc = fc - c0 as f64;
            let v = field[r0 * src + c0] * (1.0 - wr) * (1.0 - wc)
                + field[r0 * src + c1] * (1.0 - wr) * wc
                + field[r1 * src + c0] * wr * (1.0 - wc)
                + field[r1 * src + c1] * wr * wc;
            out.push(v);
        }
    }
    out
}

fn standardize(xs: &mut [f32]) {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let inv = 1.0 / var.sqrt().max(1e-12);
    for v in xs.iter_mut() {
        *v = ((*v as f64 - mean) * inv) as f32;
    }
}

fn std_of(xs: &[f32]) -> f32 {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bilinear_identity_and_constant() {
        let f = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(bilinear(&f, 2, 2), f);
        let c = vec![5.0; 9];
        let up = bilinear(&c, 3, 7);
        assert!(up.iter().all(|&v| (v - 5.0).abs() < 1e-12));
        assert_eq!(up.len(), 49);
    }

    #[test]
    fn bilinear_preserves_corners() {
        let f = vec![0.0, 1.0, 2.0, 3.0]; // 2x2
        let up = bilinear(&f, 2, 5);
        assert!((up[0] - 0.0).abs() < 1e-12);
        assert!((up[4] - 1.0).abs() < 1e-12);
        assert!((up[20] - 2.0).abs() < 1e-12);
        assert!((up[24] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn load_roundtrip_via_pipeline_export() {
        use crate::coordinator::{Pipeline, PipelineConfig};
        let dir = std::env::temp_dir().join("skr_fno_ds");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = PipelineConfig::default();
        cfg.unknowns = 64; // 8x8 grid
        cfg.count = 10;
        cfg.out_dir = Some(dir.clone());
        Pipeline::new(cfg).run().unwrap();
        let ds = FnoDataset::load(&dir, 16, 0.2, 0).unwrap();
        assert_eq!(ds.count, 10);
        assert_eq!(ds.train_idx.len(), 8);
        assert_eq!(ds.test_idx.len(), 2);
        let (x, y) = ds.batch(&ds.train_idx[..2].to_vec());
        assert_eq!(x.len(), 2 * 16 * 16);
        assert_eq!(y.len(), 2 * 16 * 16);
        assert!(x.iter().all(|v| v.is_finite()));
        // perfect predictions give ~zero error
        let ids = [0usize, 1];
        let (_, t) = ds.batch(&ids);
        assert!(ds.relative_l2(&ids, &t) < 1e-9);
    }
}
