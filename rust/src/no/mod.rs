//! Neural-operator integration: dataset → FNO tensors ([`data`]) and the
//! training loop over the AOT-compiled train step ([`trainer`]). Used by the
//! end-to-end example and the Table-33 validity experiment.

pub mod data;
pub mod trainer;

pub use data::FnoDataset;
pub use trainer::{TrainReport, Trainer};
