//! NumPy `.npy` v1.0 reader/writer for f32/f64 arrays.
//!
//! The generated datasets are written as `.npy` so the python side (pytest,
//! notebooks, FNO sanity checks) can `np.load` them directly, and so the
//! AOT-trained FNO inputs round-trip without a bespoke format.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Dtype tag for the arrays we support.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F64,
}

impl Dtype {
    fn descr(self) -> &'static str {
        match self {
            Dtype::F32 => "<f4",
            Dtype::F64 => "<f8",
        }
    }
}

/// A dense row-major array with shape metadata, as stored in `.npy`.
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
    pub dtype: Dtype,
}

impl NpyArray {
    pub fn f64(shape: Vec<usize>, data: Vec<f64>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        NpyArray { shape, data, dtype: Dtype::F64 }
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f64>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        NpyArray { shape, data, dtype: Dtype::F32 }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// f32 copy of the payload (for PJRT literals).
    pub fn as_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

fn header_string(dtype: Dtype, shape: &[usize]) -> String {
    let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    let tup = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", dims[0]),
        _ => format!("({})", dims.join(", ")),
    };
    format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        dtype.descr(),
        tup
    )
}

/// Write an array to `.npy` (v1.0).
pub fn write(path: &Path, arr: &NpyArray) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut header = header_string(arr.dtype, &arr.shape);
    // Total header (magic 6 + version 2 + len 2 + dict) must be a multiple of 64.
    let base = 6 + 2 + 2;
    let pad = 64 - ((base + header.len() + 1) % 64);
    header.push_str(&" ".repeat(pad % 64));
    header.push('\n');

    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    match arr.dtype {
        Dtype::F64 => {
            for &x in &arr.data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Dtype::F32 => {
            for &x in &arr.data {
                f.write_all(&(x as f32).to_le_bytes())?;
            }
        }
    }
    Ok(())
}

/// Read a `.npy` file written by us or by numpy (little-endian f4/f8 only).
pub fn read(path: &Path) -> Result<NpyArray> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        bail!("{}: not an npy file", path.display());
    }
    let header_len = if magic[6] == 1 {
        let mut b = [0u8; 2];
        f.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 4];
        f.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);

    let dtype = if header.contains("<f8") {
        Dtype::F64
    } else if header.contains("<f4") {
        Dtype::F32
    } else {
        bail!("unsupported dtype in header: {header}");
    };
    if header.contains("'fortran_order': True") {
        bail!("fortran_order arrays not supported");
    }
    let shape = parse_shape(&header)?;
    let count: usize = shape.iter().product();
    let mut data = Vec::with_capacity(count);
    match dtype {
        Dtype::F64 => {
            let mut buf = vec![0u8; count * 8];
            f.read_exact(&mut buf)?;
            for c in buf.chunks_exact(8) {
                data.push(f64::from_le_bytes(c.try_into().unwrap()));
            }
        }
        Dtype::F32 => {
            let mut buf = vec![0u8; count * 4];
            f.read_exact(&mut buf)?;
            for c in buf.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().unwrap()) as f64);
            }
        }
    }
    Ok(NpyArray { shape, data, dtype })
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let start = header.find("'shape':").context("no shape key")? + 8;
    let rest = &header[start..];
    let open = rest.find('(').context("no shape tuple")?;
    let close = rest.find(')').context("unclosed shape tuple")?;
    let inner = &rest[open + 1..close];
    let mut shape = Vec::new();
    for tok in inner.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        shape.push(tok.parse::<usize>().with_context(|| format!("bad dim {tok:?}"))?);
    }
    Ok(shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f64() {
        let dir = std::env::temp_dir().join("skr_npy_test");
        let p = dir.join("a.npy");
        let arr = NpyArray::f64(vec![3, 4], (0..12).map(|i| i as f64 * 0.5).collect());
        write(&p, &arr).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back.shape, vec![3, 4]);
        assert_eq!(back.data, arr.data);
        assert_eq!(back.dtype, Dtype::F64);
    }

    #[test]
    fn roundtrip_f32_and_scalar_shapes() {
        let dir = std::env::temp_dir().join("skr_npy_test");
        let p = dir.join("b.npy");
        let arr = NpyArray::f32(vec![5], vec![1.5, -2.0, 0.0, 3.25, 4.0]);
        write(&p, &arr).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back.shape, vec![5]);
        assert_eq!(back.data, arr.data);
        assert_eq!(back.dtype, Dtype::F32);
    }

    #[test]
    fn header_is_64_aligned() {
        let dir = std::env::temp_dir().join("skr_npy_test");
        let p = dir.join("c.npy");
        write(&p, &NpyArray::f64(vec![2, 2, 2], vec![0.0; 8])).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }
}
