//! Tiny JSON value + emitter/parser (enough for artifact manifests and
//! results files; serde is unavailable offline).

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }

    /// Read exactly four hex digits (one `\uXXXX` code unit), bounds-checked
    /// so a truncated escape is a parse error rather than a panic.
    fn hex4(&mut self) -> Result<u32> {
        let end = self.i.checked_add(4).filter(|&e| e <= self.b.len());
        let Some(end) = end else { bail!("truncated \\u escape at byte {}", self.i) };
        let digits = &self.b[self.i..end];
        if !digits.iter().all(|b| b.is_ascii_hexdigit()) {
            bail!("bad \\u escape at byte {}", self.i);
        }
        let code = u32::from_str_radix(std::str::from_utf8(digits)?, 16)?;
        self.i = end;
        Ok(code)
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: must pair with `\uDC00..\uDFFF`.
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        bail!("unpaired surrogate \\u{hi:04x} at byte {}", self.i);
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    bail!("unpaired surrogate \\u{hi:04x} at byte {}", self.i);
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                bail!("unpaired low surrogate \\u{hi:04x} at byte {}", self.i);
                            } else {
                                hi
                            };
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            continue;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::Str("fno_train_step".into())),
            ("inputs", Json::Arr(vec![Json::Num(3.0), Json::Num(4.5)])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.dump();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_numpyish() {
        let j = Json::parse(r#"{"shape": [16, 32, 32, 1], "dtype": "f32"}"#).unwrap();
        let shape: Vec<usize> = j.get("shape").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![16, 32, 32, 1]);
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escapes_decode() {
        assert_eq!(Json::parse("\"A\\u00e9\"").unwrap(), Json::Str("Aé".into()));
        // Astral-plane characters arrive as UTF-16 surrogate pairs.
        assert_eq!(Json::parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"x\\ud834\\udd1ey\"").unwrap(), Json::Str("x\u{1D11E}y".into()));
        // Literal (non-escaped) multibyte characters still pass through.
        assert_eq!(Json::parse("\"😀\"").unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn truncated_unicode_escape_is_error_not_panic() {
        // These used to slice out of bounds (untrusted request bodies hit this).
        for bad in ["\"\\u", "\"\\u1", "\"\\u12", "\"\\u123", "\"\\u123\""] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
        // In-bounds but non-hex digits.
        assert!(Json::parse("\"\\uzzzz\"").is_err());
        assert!(Json::parse("\"\\u12g4\"").is_err());
    }

    #[test]
    fn unpaired_surrogates_are_errors() {
        assert!(Json::parse("\"\\ud83d\"").is_err()); // lone high
        assert!(Json::parse("\"\\ud83dxx\"").is_err()); // high + literal text
        assert!(Json::parse("\"\\ud83d\\n\"").is_err()); // high + non-u escape
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err()); // high + non-low escape
        assert!(Json::parse("\"\\ude00\"").is_err()); // lone low
        assert!(Json::parse("\"\\ud83d\\u12\"").is_err()); // high + truncated low
    }

    #[test]
    fn surrogate_pair_roundtrips_through_dump() {
        let j = Json::Str("mix 😀 and \u{1D11E}".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }
}
