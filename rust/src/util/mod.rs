//! Small self-contained utilities: PRNG, CLI parsing, `.npy` IO, JSON/CSV
//! emission, timers, table printing and a lightweight property-testing
//! micro-framework (the container's cargo registry is offline, so the usual
//! crates — clap, serde, criterion, proptest — are replaced by these).

pub mod args;
pub mod json;
pub mod npy;
pub mod prng;
pub mod propcheck;
pub mod shared;
pub mod table;
pub mod timer;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Ordinary least-squares slope of y against x (used for the paper's Fig 11/12
/// high-precision convergence-slope fits).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.len() < 2 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    if sxx == 0.0 {
        0.0
    } else {
        sxy / sxx * (n / n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-15);
        assert!((std_dev(&[1.0, 1.0, 1.0])).abs() < 1e-15);
    }

    #[test]
    fn slope_of_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        assert!((ols_slope(&x, &y) - 2.0).abs() < 1e-12);
    }
}
