//! Minimal CLI argument parser (the registry is offline, so no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args
//! and subcommands. Typed getters parse on demand and report readable errors.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, key→value options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.opts.insert(rest.to_string(), v);
                        }
                        _ => {
                            out.opts.insert(rest.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a readable message on bad input.
    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(s) => match s.parse() {
                Ok(v) => v,
                Err(e) => panic!("--{key}: cannot parse {s:?}: {e}"),
            },
        }
    }

    /// Boolean flag (present, `=true`, or `=1`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Positional arguments after the subcommand.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(s) => s.split(',').map(|t| t.trim().to_string()).filter(|t| !t.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("generate --family darcy --n 6400 --tol=1e-8 --sort extra");
        assert_eq!(a.command.as_deref(), Some("generate"));
        assert_eq!(a.get("family"), Some("darcy"));
        assert_eq!(a.num_or("n", 0usize), 6400);
        assert!((a.num_or("tol", 0.0f64) - 1e-8).abs() < 1e-20);
        assert_eq!(a.get("sort"), Some("extra"));
    }

    #[test]
    fn boolean_flags() {
        let a = parse("run --full --quiet --k 5");
        assert!(a.flag("full"));
        assert!(a.flag("quiet"));
        assert_eq!(a.num_or("k", 0usize), 5);
        assert!(!a.flag("absent"));
    }

    #[test]
    fn lists() {
        let a = parse("t --preconds jacobi,sor, ilu");
        assert_eq!(a.list_or("preconds", &[]), vec!["jacobi", "sor"]);
        assert_eq!(a.positional(), &["ilu".to_string()]);
    }
}
