//! Wall-clock timing helpers for the bench harness and pipeline metrics.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Run a closure `reps` times and return the minimum wall time (seconds) —
/// the standard noise-robust micro-bench statistic.
pub fn best_of<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    assert!(reps >= 1);
    let (mut out, mut best) = timed(&mut f);
    for _ in 1..reps {
        let (o, s) = timed(&mut f);
        if s < best {
            best = s;
            out = o;
        }
    }
    (out, best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_measures_something() {
        let (v, s) = timed(|| (0..10_000).sum::<u64>());
        assert_eq!(v, 49_995_000);
        assert!(s >= 0.0);
    }

    #[test]
    fn best_of_returns_min() {
        let (_, s) = best_of(3, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(s >= 0.0005);
    }

    #[test]
    fn timer_is_monotonic() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(a >= 0.0);
        assert!(b >= a, "{b} < {a}");
    }

    #[test]
    fn best_of_keeps_result_of_fastest_rep() {
        // Each rep returns a distinct value; whichever rep was fastest, the
        // returned value must be internally consistent with `reps` calls.
        let mut calls = 0;
        let (v, s) = best_of(5, || {
            calls += 1;
            42
        });
        assert_eq!(v, 42);
        assert_eq!(calls, 5);
        assert!(s >= 0.0);
    }

    #[test]
    #[should_panic]
    fn best_of_rejects_zero_reps() {
        let _ = best_of(0, || ());
    }
}
