//! Aligned plain-text table printer — used by the bench harnesses to emit
//! the same row layout as the paper's tables, plus a CSV mirror under
//! `results/` for downstream plotting.

use std::fmt::Write as _;
use std::path::Path;

/// A table under construction: a header row plus data rows.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "## {}", self.title);
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                let _ = write!(s, " {:width$} |", c, width = width);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    /// Write a CSV mirror.
    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(s, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(path, s)?;
        Ok(())
    }
}

/// Format a speedup pair like the paper's "2.92/21.1" cells.
pub fn ratio_cell(time_ratio: f64, iter_ratio: f64) -> String {
    format!("{:.2}/{:.3}", time_ratio, iter_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.lines().count() >= 4);
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn csv_roundtrip_quoting() {
        let dir = std::env::temp_dir().join("skr_table_test");
        let p = dir.join("t.csv");
        let mut t = Table::new("", &["k", "v"]);
        t.row(vec!["a,b".into(), "c\"d".into()]);
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"a,b\""));
        assert!(s.contains("\"c\"\"d\""));
    }
}
