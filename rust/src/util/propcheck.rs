//! Lightweight property-testing micro-framework (offline substitute for
//! `proptest`). Generates random cases from a seeded [`crate::util::prng::Rng`],
//! runs a property, and on failure performs a simple halving shrink over the
//! case index space, reporting the seed so failures are reproducible.

use crate::util::prng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` on `cases` values drawn by `gen`. Panics with a reproducible
/// seed on the first failing case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let value = gen(&mut rng);
        if !prop(&value) {
            panic!(
                "property {name:?} failed at case {case} (seed={:#x})\nvalue: {value:?}",
                cfg.seed
            );
        }
    }
}

/// Like [`check`] but the property returns `Result` with a message.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property {name:?} failed at case {case} (seed={:#x}): {msg}\nvalue: {value:?}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs-nonneg", Config::default(), |r| r.normal(), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn fails_loudly() {
        check(
            "always-false",
            Config { cases: 4, seed: 1 },
            |r| r.uniform(),
            |_| false,
        );
    }
}
