//! Lazily-initialised shared values — the cell behind the per-family
//! sparsity/stiffness caches.
//!
//! [`SharedOnce`] is a `OnceLock<Arc<T>>` that families embed so every
//! `sample()` (across all pipeline workers) hands out the same `Arc`.
//! Cloning a family clones the cached `Arc`, not the payload, so clones keep
//! sharing structure with the original.

use std::fmt;
use std::sync::{Arc, OnceLock};

/// A write-once, share-many cell holding an `Arc<T>`.
pub struct SharedOnce<T>(OnceLock<Arc<T>>);

impl<T> SharedOnce<T> {
    pub fn new() -> SharedOnce<T> {
        SharedOnce(OnceLock::new())
    }

    /// The cached value, initialising it from `f` on first use. Concurrent
    /// first calls may both run `f`; one result wins and all callers share it.
    pub fn get_or_init(&self, f: impl FnOnce() -> T) -> Arc<T> {
        self.0.get_or_init(|| Arc::new(f())).clone()
    }

    /// Fallible variant: the error is returned and nothing is cached, so a
    /// later call retries.
    pub fn get_or_try_init<E>(&self, f: impl FnOnce() -> Result<T, E>) -> Result<Arc<T>, E> {
        if let Some(v) = self.0.get() {
            return Ok(v.clone());
        }
        let v = Arc::new(f()?);
        Ok(self.0.get_or_init(|| v).clone())
    }

    /// The cached value, if initialised.
    pub fn get(&self) -> Option<Arc<T>> {
        self.0.get().cloned()
    }
}

impl<T> Default for SharedOnce<T> {
    fn default() -> Self {
        SharedOnce::new()
    }
}

impl<T> Clone for SharedOnce<T> {
    fn clone(&self) -> Self {
        let cell = OnceLock::new();
        if let Some(v) = self.0.get() {
            let _ = cell.set(v.clone());
        }
        SharedOnce(cell)
    }
}

impl<T> fmt::Debug for SharedOnce<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.0.get() {
            Some(_) => f.write_str("SharedOnce(set)"),
            None => f.write_str("SharedOnce(unset)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initialises_once_and_shares() {
        let cell: SharedOnce<Vec<usize>> = SharedOnce::new();
        assert!(cell.get().is_none());
        let a = cell.get_or_init(|| vec![1, 2, 3]);
        let b = cell.get_or_init(|| vec![9, 9, 9]);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(*b, vec![1, 2, 3]);
    }

    #[test]
    fn clone_carries_the_cached_arc() {
        let cell: SharedOnce<usize> = SharedOnce::new();
        let a = cell.get_or_init(|| 7);
        let cloned = cell.clone();
        assert!(Arc::ptr_eq(&a, &cloned.get().unwrap()));
    }

    #[test]
    fn try_init_retries_after_error() {
        let cell: SharedOnce<usize> = SharedOnce::new();
        let err: Result<Arc<usize>, &str> = cell.get_or_try_init(|| Err("nope"));
        assert!(err.is_err());
        let ok = cell.get_or_try_init(|| Ok::<usize, &str>(5)).unwrap();
        assert_eq!(*ok, 5);
    }
}
