//! Deterministic PRNG: SplitMix64 core with uniform / normal / permutation
//! helpers. Every stochastic component of the pipeline (GRF sampling,
//! Chebyshev coefficients, boundary temperatures, shuffling) draws from this
//! so whole experiments are reproducible from a single seed.

/// SplitMix64 generator — tiny, fast, passes BigCrush for our purposes and,
/// crucially, has trivially splittable streams (`split`) so each worker /
/// problem instance gets an independent stream derived from the master seed.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed ^ 0x9E37_79B9_7F4A_7C15,
        }
    }

    /// Derive an independent stream (for worker `i`, sample `j`, ...).
    pub fn split(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state.wrapping_add(stream.wrapping_mul(0xBF58_476D_1CE4_E5B9)));
        r.next_u64(); // decorrelate
        Rng::new(r.next_u64())
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (uses two uniforms per pair; the spare
    /// is intentionally dropped to keep the stream stateless).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(42);
        let xs: Vec<f64> = (0..20_000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs = r.normals(40_000);
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn split_streams_differ() {
        let r = Rng::new(1);
        let mut a = r.split(0);
        let mut b = r.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
