//! Iteration-level solver instrumentation.
//!
//! [`SolveObserver`] is threaded through `gmres` and `gcrodr`; every hook
//! has an empty default body, so the [`NoopObserver`] compiles to nothing
//! and the solver hot loop is unchanged when tracing is off. Observers
//! receive *copies* of solver state (iteration counts, residual norms) and
//! can never perturb the numerics — the observer-on and observer-off paths
//! execute bit-identical arithmetic.

use crate::solver::stats::SolveStats;

/// Hooks called by the Krylov solvers at cycle granularity.
///
/// All methods have no-op defaults; implement only what you need.
pub trait SolveObserver {
    /// Solve begins on an `n`-unknown system with initial relative residual
    /// `rel`.
    fn on_start(&mut self, n: usize, rel: f64) {
        let _ = (n, rel);
    }

    /// A restart/deflation cycle finished: `iters` cumulative inner
    /// iterations so far, `rel` the current relative residual estimate.
    fn on_cycle(&mut self, iters: usize, rel: f64) {
        let _ = (iters, rel);
    }

    /// A recycle space of dimension `k` was installed (GCRO-DR only):
    /// either re-orthonormalized from the previous system (`reused=false`),
    /// or carried verbatim because the operator was unchanged
    /// (`reused=true`).
    fn on_recycle(&mut self, k: usize, reused: bool) {
        let _ = (k, reused);
    }

    /// A fresh recycle space of dimension `k` was harvested from this
    /// cycle's harmonic Ritz problem.
    fn on_harvest(&mut self, k: usize) {
        let _ = k;
    }

    /// Solve finished; `stats` is exactly what the solver returns.
    fn on_end(&mut self, stats: &SolveStats) {
        let _ = stats;
    }
}

/// The zero-cost default: every hook is the empty inherent default.
pub struct NoopObserver;

impl SolveObserver for NoopObserver {}

/// One recorded solver event (the in-memory mirror of a trace line).
#[derive(Debug, Clone, PartialEq)]
pub enum SolveEvent {
    Start { n: usize, rel: f64 },
    Cycle { iters: usize, rel: f64 },
    Recycle { k: usize, reused: bool },
    Harvest { k: usize },
    End { iters: usize, seconds: f64, rel_residual: f64, stop: &'static str },
}

/// Buffers every event of one solve, for forwarding to a trace sink (or
/// asserting on in tests).
#[derive(Default)]
pub struct RecordingObserver {
    pub events: Vec<SolveEvent>,
}

impl RecordingObserver {
    pub fn new() -> RecordingObserver {
        RecordingObserver::default()
    }

    /// Cycle events in (iters, rel) form — the Fig-1/11/12 series.
    pub fn cycles(&self) -> Vec<(usize, f64)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                SolveEvent::Cycle { iters, rel } => Some((*iters, *rel)),
                _ => None,
            })
            .collect()
    }

    /// Largest recycle-space dimension seen during this solve.
    pub fn max_deflation_dim(&self) -> usize {
        self.events
            .iter()
            .filter_map(|e| match e {
                SolveEvent::Recycle { k, .. } | SolveEvent::Harvest { k } => Some(*k),
                _ => None,
            })
            .max()
            .unwrap_or(0)
    }
}

impl SolveObserver for RecordingObserver {
    fn on_start(&mut self, n: usize, rel: f64) {
        self.events.push(SolveEvent::Start { n, rel });
    }

    fn on_cycle(&mut self, iters: usize, rel: f64) {
        self.events.push(SolveEvent::Cycle { iters, rel });
    }

    fn on_recycle(&mut self, k: usize, reused: bool) {
        self.events.push(SolveEvent::Recycle { k, reused });
    }

    fn on_harvest(&mut self, k: usize) {
        self.events.push(SolveEvent::Harvest { k });
    }

    fn on_end(&mut self, stats: &SolveStats) {
        self.events.push(SolveEvent::End {
            iters: stats.iters,
            seconds: stats.seconds,
            rel_residual: stats.rel_residual,
            stop: stats.stop.label(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::stats::StopReason;

    #[test]
    fn recording_observer_orders_events() {
        let mut obs = RecordingObserver::new();
        obs.on_start(100, 1.0);
        obs.on_recycle(5, true);
        obs.on_cycle(30, 1e-3);
        obs.on_harvest(4);
        obs.on_cycle(55, 1e-9);
        let stats = SolveStats {
            iters: 55,
            seconds: 0.1,
            rel_residual: 1e-9,
            stop: StopReason::Converged,
            trace: vec![],
        };
        obs.on_end(&stats);
        assert_eq!(obs.events.len(), 6);
        assert_eq!(obs.cycles(), vec![(30, 1e-3), (55, 1e-9)]);
        assert_eq!(obs.max_deflation_dim(), 5);
        assert!(matches!(obs.events.last(), Some(SolveEvent::End { stop: "converged", .. })));
    }

    #[test]
    fn noop_observer_accepts_all_hooks() {
        let mut obs = NoopObserver;
        obs.on_start(10, 1.0);
        obs.on_cycle(1, 0.5);
        obs.on_recycle(2, false);
        obs.on_harvest(2);
        let stats = SolveStats {
            iters: 1,
            seconds: 0.0,
            rel_residual: 0.5,
            stop: StopReason::MaxIters,
            trace: vec![],
        };
        obs.on_end(&stats);
    }
}
