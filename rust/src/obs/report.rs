//! `skr report <trace.jsonl>` — aggregate a trace into the paper's
//! table-style summary: percentile solve times, iteration histogram,
//! per-worker timeline/utilization, backpressure totals, stage breakdown.
//!
//! The aggregation is exact (it replays the per-solve events), so the mean
//! iterations/solve seconds it prints reproduce `RunMetrics` for the run
//! that emitted the trace.

use crate::obs::hist::Histogram;
use crate::solver::SolveCounters;
use crate::util::args::Args;
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// Per-worker rollup parsed from `worker` events (or rebuilt from solves).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerLine {
    pub systems: usize,
    pub busy_seconds: f64,
    pub wall_seconds: f64,
    pub backpressure_seconds: f64,
}

impl WorkerLine {
    pub fn utilization(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.busy_seconds / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Everything `skr report` aggregates out of one trace file.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub systems: usize,
    pub total_iters: usize,
    pub solve_seconds: f64,
    pub max_iter_hits: usize,
    pub breakdowns: usize,
    pub cycles: usize,
    pub recycle_installs: usize,
    /// Sorted per-system solve times (exact percentiles).
    pub solve_times: Vec<f64>,
    pub rel_residual_worst: f64,
    pub rel_residual_sum: f64,
    pub iters_hist: Histogram,
    pub time_hist: Histogram,
    pub per_worker: BTreeMap<usize, WorkerLine>,
    /// Top-level stage name → total seconds, from `span` events.
    pub stages: BTreeMap<String, f64>,
    /// Engines seen in `solve` events (usually one; two for `compare`).
    pub engines: Vec<String>,
    /// `run` summary events seen (0 for traces from older runs).
    pub run_events: usize,
    /// Structure/scratch reuse tallies from `run` events: systems that
    /// shared the previous `Arc<Sparsity>`, preconditioner builds that
    /// skipped the symbolic phase, and solves rerun on pooled buffers.
    pub sparsity_reuse: usize,
    pub symbolic_reuse: usize,
    pub workspace_reuse: usize,
    /// Deterministic solver op counters from `run` events (all zero for
    /// traces emitted before the counters existed).
    pub counters: SolveCounters,
    pub parse_errors: usize,
}

impl Default for TraceReport {
    fn default() -> Self {
        TraceReport {
            systems: 0,
            total_iters: 0,
            solve_seconds: 0.0,
            max_iter_hits: 0,
            breakdowns: 0,
            cycles: 0,
            recycle_installs: 0,
            solve_times: Vec::new(),
            rel_residual_worst: 0.0,
            rel_residual_sum: 0.0,
            iters_hist: Histogram::iters_buckets(),
            time_hist: Histogram::seconds_buckets(),
            per_worker: BTreeMap::new(),
            stages: BTreeMap::new(),
            engines: Vec::new(),
            run_events: 0,
            sparsity_reuse: 0,
            symbolic_reuse: 0,
            workspace_reuse: 0,
            counters: SolveCounters::default(),
            parse_errors: 0,
        }
    }
}

impl TraceReport {
    pub fn from_file(path: &Path) -> Result<TraceReport> {
        // A writer killed mid-line can leave a torn final line — including
        // a multibyte char cut in half, which `read_to_string` would reject
        // outright. Decode lossily so the torn tail becomes one unparseable
        // line (counted in `parse_errors`), mirroring the tolerance of
        // `service::journal` replay.
        let bytes =
            std::fs::read(path).with_context(|| format!("reading trace {}", path.display()))?;
        let text = String::from_utf8_lossy(&bytes);
        Self::from_lines(text.lines())
    }

    pub fn from_lines<'a>(lines: impl Iterator<Item = &'a str>) -> Result<TraceReport> {
        let mut r = TraceReport::default();
        let mut saw_any = false;
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            saw_any = true;
            let Ok(ev) = Json::parse(line) else {
                r.parse_errors += 1;
                continue;
            };
            match ev.get("ev").and_then(|e| e.as_str()) {
                Some("solve") => r.absorb_solve(&ev),
                Some("cycle") => r.cycles += 1,
                Some("recycle") => r.recycle_installs += 1,
                Some("worker") => r.absorb_worker(&ev),
                Some("span") => r.absorb_span(&ev),
                Some("run") => r.absorb_run(&ev),
                // meta / unknown events are informational only.
                _ => {}
            }
        }
        if !saw_any {
            bail!("trace is empty");
        }
        r.solve_times.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Ok(r)
    }

    fn absorb_solve(&mut self, ev: &Json) {
        let num = |k: &str| ev.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        self.systems += 1;
        let iters = num("iters") as usize;
        let seconds = num("seconds");
        self.total_iters += iters;
        self.solve_seconds += seconds;
        self.solve_times.push(seconds);
        self.iters_hist.observe(iters as f64);
        self.time_hist.observe(seconds);
        let rel = num("rel_residual");
        self.rel_residual_sum += rel;
        if rel > self.rel_residual_worst {
            self.rel_residual_worst = rel;
        }
        match ev.get("stop").and_then(|s| s.as_str()) {
            Some("max_iters") => self.max_iter_hits += 1,
            Some("breakdown") => self.breakdowns += 1,
            _ => {}
        }
        if let Some(engine) = ev.get("engine").and_then(|e| e.as_str()) {
            if !self.engines.iter().any(|e| e == engine) {
                self.engines.push(engine.to_string());
            }
        }
    }

    fn absorb_worker(&mut self, ev: &Json) {
        let num = |k: &str| ev.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let Some(w) = ev.get("worker").and_then(|v| v.as_usize()) else { return };
        let line = self.per_worker.entry(w).or_default();
        line.systems += num("systems") as usize;
        line.busy_seconds += num("busy_seconds");
        line.wall_seconds += num("wall_seconds");
        line.backpressure_seconds += num("backpressure_seconds");
    }

    fn absorb_run(&mut self, ev: &Json) {
        let num = |k: &str| ev.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        self.run_events += 1;
        self.sparsity_reuse += num("sparsity_reuse") as usize;
        self.symbolic_reuse += num("symbolic_reuse") as usize;
        self.workspace_reuse += num("workspace_reuse") as usize;
        self.counters.matvecs += num("matvecs") as u64;
        self.counters.precond_applies += num("precond_applies") as u64;
        self.counters.ortho_flops += num("ortho_flops") as u64;
        self.counters.recycle_reseeds += num("recycle_reseeds") as u64;
        self.counters.recycle_carries += num("recycle_carries") as u64;
        self.counters.harvests += num("harvests") as u64;
    }

    fn absorb_span(&mut self, ev: &Json) {
        let Some(name) = ev.get("name").and_then(|v| v.as_str()) else { return };
        // Only top-level stages go into the breakdown; nested worker and
        // per-system spans are already rolled up by `worker` events.
        if name.contains('/') {
            return;
        }
        let secs = ev.get("seconds").and_then(|v| v.as_f64()).unwrap_or(0.0);
        *self.stages.entry(name.to_string()).or_insert(0.0) += secs;
    }

    pub fn mean_iters(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.total_iters as f64 / self.systems as f64
        }
    }

    pub fn mean_time(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.solve_seconds / self.systems as f64
        }
    }

    pub fn mean_rel_residual(&self) -> f64 {
        if self.systems == 0 {
            0.0
        } else {
            self.rel_residual_sum / self.systems as f64
        }
    }

    /// Exact q-quantile of per-system solve seconds (nearest-rank).
    pub fn time_percentile(&self, q: f64) -> f64 {
        if self.solve_times.is_empty() {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.solve_times.len() as f64).ceil().max(1.0) as usize;
        self.solve_times[rank.min(self.solve_times.len()) - 1]
    }

    pub fn backpressure_seconds(&self) -> f64 {
        self.per_worker.values().map(|w| w.backpressure_seconds).sum()
    }

    /// Render the paper-style summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: {} systems, engines [{}], {} cycle events, {} recycle installs",
            self.systems,
            self.engines.join(", "),
            self.cycles,
            self.recycle_installs
        );
        let _ = writeln!(
            out,
            "solve: mean {:.4}s / {:.1} iters per system  (p50 {:.4}s  p90 {:.4}s  p99 {:.4}s)",
            self.mean_time(),
            self.mean_iters(),
            self.time_percentile(0.50),
            self.time_percentile(0.90),
            self.time_percentile(0.99),
        );
        let _ = writeln!(
            out,
            "residual: worst {:.3e}  mean {:.3e};  max-iter hits {}  breakdowns {}",
            self.rel_residual_worst,
            self.mean_rel_residual(),
            self.max_iter_hits,
            self.breakdowns
        );
        if self.run_events > 0 {
            let _ = writeln!(
                out,
                "reuse: sparsity {}/{}  symbolic {}/{}  workspace {}/{}",
                self.sparsity_reuse,
                self.systems,
                self.symbolic_reuse,
                self.systems,
                self.workspace_reuse,
                self.systems,
            );
            let c = &self.counters;
            if c != &SolveCounters::default() {
                let _ = writeln!(
                    out,
                    "counters: matvecs {}  precond {}  ortho_flops {}  recycle carry/reseed/harvest {}/{}/{}",
                    c.matvecs,
                    c.precond_applies,
                    c.ortho_flops,
                    c.recycle_carries,
                    c.recycle_reseeds,
                    c.harvests,
                );
            }
        }
        if !self.stages.is_empty() {
            let stages: Vec<String> =
                self.stages.iter().map(|(k, v)| format!("{k} {v:.3}s")).collect();
            let _ = writeln!(out, "stages: {}", stages.join("  "));
        }
        if !self.per_worker.is_empty() {
            let mut t = Table::new(
                "per-worker timeline",
                &["worker", "systems", "busy_s", "wall_s", "backpressure_s", "utilization"],
            );
            for (w, line) in &self.per_worker {
                t.row(vec![
                    w.to_string(),
                    line.systems.to_string(),
                    format!("{:.3}", line.busy_seconds),
                    format!("{:.3}", line.wall_seconds),
                    format!("{:.4}", line.backpressure_seconds),
                    format!("{:.1}%", line.utilization() * 100.0),
                ]);
            }
            let _ = write!(out, "{}", t.render());
            let _ = writeln!(
                out,
                "backpressure total: {:.4}s blocked in writer channel",
                self.backpressure_seconds()
            );
        }
        let _ = write!(out, "{}", self.iters_hist.render("iterations per system"));
        let _ = write!(out, "{}", self.time_hist.render("solve seconds per system"));
        if self.parse_errors > 0 {
            let _ = writeln!(out, "WARNING: {} unparseable trace lines skipped", self.parse_errors);
        }
        out
    }
}

/// CLI entry: `skr report <trace.jsonl> [--prometheus]`.
pub fn run(args: &Args) -> Result<()> {
    let Some(path) = args.positional().first() else {
        bail!("usage: skr report <trace.jsonl> [--prometheus]");
    };
    let report = TraceReport::from_file(Path::new(path))?;
    print!("{}", report.render());
    if args.flag("prometheus") {
        let mut text = String::new();
        let _ = writeln!(text, "# TYPE skr_systems_total counter");
        let _ = writeln!(text, "skr_systems_total {}", report.systems);
        let _ = writeln!(text, "# TYPE skr_max_iter_hits_total counter");
        let _ = writeln!(text, "skr_max_iter_hits_total {}", report.max_iter_hits);
        report.iters_hist.prometheus("skr_solve_iters", &mut text);
        report.time_hist.prometheus("skr_solve_seconds", &mut text);
        print!("{text}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_solve_and_worker_events() {
        let lines = [
            r#"{"ev":"meta","count":3}"#,
            r#"{"ev":"span","name":"gen","worker":null,"start":0,"seconds":0.5}"#,
            r#"{"ev":"span","name":"solve/w0/sys0","worker":0,"start":1,"seconds":0.1}"#,
            r#"{"ev":"cycle","id":0,"worker":0,"iters":30,"rel":0.001}"#,
            r#"{"ev":"recycle","id":1,"worker":0,"k":5,"reused":false}"#,
            r#"{"ev":"solve","id":0,"worker":0,"engine":"SKR","n":100,"iters":40,"seconds":0.2,"rel_residual":1e-9,"stop":"converged","recycle_k":0}"#,
            r#"{"ev":"solve","id":1,"worker":0,"engine":"SKR","n":100,"iters":20,"seconds":0.1,"rel_residual":2e-9,"stop":"converged","recycle_k":5}"#,
            r#"{"ev":"solve","id":2,"worker":1,"engine":"SKR","n":100,"iters":60,"seconds":0.6,"rel_residual":5e-7,"stop":"max_iters","recycle_k":5}"#,
            r#"{"ev":"worker","worker":0,"systems":2,"busy_seconds":0.3,"wall_seconds":0.4,"backpressure_seconds":0.05,"utilization":0.75}"#,
            r#"{"ev":"worker","worker":1,"systems":1,"busy_seconds":0.6,"wall_seconds":0.7,"backpressure_seconds":0.01,"utilization":0.857}"#,
            r#"{"ev":"run","systems":3,"total_iters":120,"sparsity_reuse":1,"symbolic_reuse":1,"workspace_reuse":1}"#,
        ];
        let r = TraceReport::from_lines(lines.iter().copied()).unwrap();
        assert_eq!(r.systems, 3);
        assert_eq!(r.total_iters, 120);
        assert!((r.mean_iters() - 40.0).abs() < 1e-12);
        assert!((r.mean_time() - 0.3).abs() < 1e-12);
        assert_eq!(r.max_iter_hits, 1);
        assert_eq!(r.cycles, 1);
        assert_eq!(r.recycle_installs, 1);
        assert_eq!(r.engines, vec!["SKR".to_string()]);
        assert!((r.rel_residual_worst - 5e-7).abs() < 1e-20);
        // Exact percentiles over [0.1, 0.2, 0.6].
        assert!((r.time_percentile(0.5) - 0.2).abs() < 1e-12);
        assert!((r.time_percentile(1.0) - 0.6).abs() < 1e-12);
        // Worker rollups.
        assert_eq!(r.per_worker.len(), 2);
        assert!((r.per_worker[&0].utilization() - 0.75).abs() < 1e-12);
        assert!((r.backpressure_seconds() - 0.06).abs() < 1e-12);
        // Only the top-level span lands in stages.
        assert_eq!(r.stages.len(), 1);
        assert!((r.stages["gen"] - 0.5).abs() < 1e-12);
        // Reuse tallies come from the run event.
        assert_eq!(r.run_events, 1);
        assert_eq!(r.sparsity_reuse, 1);
        assert_eq!(r.symbolic_reuse, 1);
        assert_eq!(r.workspace_reuse, 1);
        // Rendering mentions the headline numbers.
        let text = r.render();
        assert!(text.contains("3 systems"));
        assert!(text.contains("per-worker timeline"));
        assert!(text.contains("reuse: sparsity 1/3  symbolic 1/3  workspace 1/3"));
        assert_eq!(r.parse_errors, 0);
    }

    #[test]
    fn run_event_counters_are_absorbed_and_rendered() {
        let lines = [
            r#"{"ev":"solve","id":0,"worker":0,"engine":"SKR","n":10,"iters":5,"seconds":0.01,"rel_residual":1e-10,"stop":"converged","recycle_k":0}"#,
            r#"{"ev":"run","systems":1,"sparsity_reuse":0,"symbolic_reuse":0,"workspace_reuse":0,"matvecs":100,"precond_applies":90,"ortho_flops":12345,"recycle_reseeds":1,"recycle_carries":2,"harvests":3}"#,
        ];
        let r = TraceReport::from_lines(lines.iter().copied()).unwrap();
        assert_eq!(r.counters.matvecs, 100);
        assert_eq!(r.counters.precond_applies, 90);
        assert_eq!(r.counters.ortho_flops, 12345);
        assert_eq!(r.counters.recycle_reseeds, 1);
        assert_eq!(r.counters.recycle_carries, 2);
        assert_eq!(r.counters.harvests, 3);
        let text = r.render();
        assert!(
            text.contains("counters: matvecs 100  precond 90  ortho_flops 12345"),
            "{text}"
        );
    }

    #[test]
    fn from_file_tolerates_torn_final_line() {
        // A crashed writer can tear the last JSONL line anywhere — including
        // mid-multibyte-char, which is invalid UTF-8. `skr report` must
        // aggregate the intact prefix instead of erroring mid-parse.
        use std::io::Write as _;
        let path = std::env::temp_dir().join(format!("skr_torn_{}.jsonl", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        writeln!(
            f,
            r#"{{"ev":"solve","id":0,"worker":0,"engine":"SKR","n":10,"iters":5,"seconds":0.01,"rel_residual":1e-10,"stop":"converged","recycle_k":0}}"#
        )
        .unwrap();
        // Torn tail: 0xC3 opens a 2-byte UTF-8 sequence that never completes.
        f.write_all(b"{\"ev\":\"solve\",\"id\":1,\"engine\":\"GMR\xC3").unwrap();
        drop(f);
        let r = TraceReport::from_file(&path).unwrap();
        assert_eq!(r.systems, 1);
        assert_eq!(r.parse_errors, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tolerates_garbage_lines_and_rejects_empty() {
        let lines = [
            "not json at all",
            r#"{"ev":"solve","id":0,"worker":0,"engine":"GMRES","n":10,"iters":5,"seconds":0.01,"rel_residual":1e-10,"stop":"converged","recycle_k":0}"#,
        ];
        let r = TraceReport::from_lines(lines.iter().copied()).unwrap();
        assert_eq!(r.systems, 1);
        assert_eq!(r.parse_errors, 1);
        assert!(TraceReport::from_lines([].iter().copied()).is_err());
    }
}
