//! Opt-in live progress line for `skr generate`.
//!
//! Workers call [`Progress::tick`] after each system; the meter redraws a
//! single stderr line (carriage return, no scroll) at most ~5×/second with
//! systems/sec, an ETA from the current rate, and the running max-iter
//! incidence. All state is atomic, so ticks from worker threads never
//! block each other; redraw throttling uses a `try_lock` so contended
//! ticks skip the draw instead of waiting.

use std::io::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shared progress meter (inert unless `enabled`).
pub struct Progress {
    total: usize,
    done: AtomicUsize,
    max_iter_hits: AtomicUsize,
    total_iters: AtomicUsize,
    epoch: Instant,
    last_draw: Mutex<f64>,
    enabled: bool,
}

impl Progress {
    pub fn new(total: usize, enabled: bool) -> Progress {
        Progress {
            total,
            done: AtomicUsize::new(0),
            max_iter_hits: AtomicUsize::new(0),
            total_iters: AtomicUsize::new(0),
            epoch: Instant::now(),
            last_draw: Mutex::new(0.0),
            enabled,
        }
    }

    /// Record one finished system (its iteration count and whether it hit
    /// the iteration cap) and maybe redraw.
    pub fn tick(&self, iters: usize, hit_cap: bool) {
        let done = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        self.total_iters.fetch_add(iters, Ordering::Relaxed);
        if hit_cap {
            self.max_iter_hits.fetch_add(1, Ordering::Relaxed);
        }
        if !self.enabled {
            return;
        }
        let now = self.epoch.elapsed().as_secs_f64();
        // Redraw at most every 200 ms (and always for the final system).
        if let Ok(mut last) = self.last_draw.try_lock() {
            if done == self.total || now - *last >= 0.2 {
                *last = now;
                self.draw(done, now);
            }
        }
    }

    fn draw(&self, done: usize, now: f64) {
        let rate = if now > 0.0 { done as f64 / now } else { 0.0 };
        let remaining = self.total.saturating_sub(done);
        let eta = if rate > 0.0 { remaining as f64 / rate } else { f64::NAN };
        let hits = self.max_iter_hits.load(Ordering::Relaxed);
        let mut err = std::io::stderr().lock();
        let _ = write!(
            err,
            "\r[skr] {done}/{} systems  {rate:.1} sys/s  ETA {eta:.0}s  max-iter hits {hits}   ",
            self.total
        );
        let _ = err.flush();
    }

    /// Terminate the progress line (call once after the run).
    pub fn finish(&self) {
        if self.enabled && self.done.load(Ordering::Relaxed) > 0 {
            let mut err = std::io::stderr().lock();
            let _ = writeln!(err);
        }
    }

    pub fn done(&self) -> usize {
        self.done.load(Ordering::Relaxed)
    }

    pub fn max_iter_hits(&self) -> usize {
        self.max_iter_hits.load(Ordering::Relaxed)
    }

    pub fn total_iters(&self) -> usize {
        self.total_iters.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_without_printing_when_disabled() {
        let p = Progress::new(5, false);
        for i in 0..5 {
            p.tick(10 + i, i == 3);
        }
        assert_eq!(p.done(), 5);
        assert_eq!(p.max_iter_hits(), 1);
        assert_eq!(p.total_iters(), 60);
        p.finish();
    }

    #[test]
    fn concurrent_ticks_are_lossless() {
        let p = Progress::new(400, false);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        p.tick(3, false);
                    }
                });
            }
        });
        assert_eq!(p.done(), 400);
        assert_eq!(p.total_iters(), 1200);
    }
}
