//! JSONL trace sink — one machine-parseable JSON object per line, shared
//! across worker threads behind a mutex (each line is written atomically).
//!
//! Event vocabulary (all events carry an `"ev"` discriminant):
//!
//! * `meta`    — run header: engine/family labels, count, threads.
//! * `span`    — `{name, worker, start, seconds}` pipeline stage timing.
//! * `solve`   — per-system outcome: `{id, worker, engine, n, iters,
//!   seconds, rel_residual, stop, recycle_k}`.
//! * `cycle`   — per-cycle residual: `{id, worker, iters, rel}`.
//! * `recycle` — recycle-space install/harvest: `{id, worker, k, reused}`.
//! * `worker`  — per-worker rollup: `{worker, systems, busy_seconds,
//!   wall_seconds, backpressure_seconds, utilization}`.
//! * `run`     — final aggregate mirroring `RunMetrics`.

use crate::obs::observe::SolveEvent;
use crate::solver::stats::SolveStats;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

/// Thread-safe line-oriented JSON writer.
pub struct TraceSink {
    w: Mutex<BufWriter<std::fs::File>>,
}

impl TraceSink {
    /// Create (truncate) the trace file.
    pub fn create(path: &Path) -> Result<TraceSink> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating trace dir {}", parent.display()))?;
            }
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(TraceSink { w: Mutex::new(BufWriter::new(f)) })
    }

    /// Write one event as a single line. IO errors are deliberately
    /// swallowed: tracing must never fail the run it observes.
    pub fn emit(&self, ev: &Json) {
        let mut line = ev.dump();
        line.push('\n');
        if let Ok(mut w) = self.w.lock() {
            let _ = w.write_all(line.as_bytes());
        }
    }

    /// Emit several events under one lock acquisition (keeps one system's
    /// events contiguous in the file).
    pub fn emit_all(&self, evs: &[Json]) {
        if let Ok(mut w) = self.w.lock() {
            for ev in evs {
                let mut line = ev.dump();
                line.push('\n');
                let _ = w.write_all(line.as_bytes());
            }
        }
    }

    pub fn flush(&self) {
        if let Ok(mut w) = self.w.lock() {
            let _ = w.flush();
        }
    }

    /// Build the `solve` event plus its buffered `cycle`/`recycle` events
    /// for one system, in file order (cycles first, outcome last).
    pub fn solve_events(
        id: usize,
        worker: usize,
        engine: &str,
        n: usize,
        stats: &SolveStats,
        events: &[SolveEvent],
    ) -> Vec<Json> {
        let mut out = Vec::with_capacity(events.len() + 1);
        let mut recycle_k = 0usize;
        for ev in events {
            match ev {
                SolveEvent::Cycle { iters, rel } => out.push(Json::obj(vec![
                    ("ev", Json::Str("cycle".into())),
                    ("id", Json::Num(id as f64)),
                    ("worker", Json::Num(worker as f64)),
                    ("iters", Json::Num(*iters as f64)),
                    ("rel", Json::Num(*rel)),
                ])),
                SolveEvent::Recycle { k, reused } => {
                    recycle_k = recycle_k.max(*k);
                    out.push(Json::obj(vec![
                        ("ev", Json::Str("recycle".into())),
                        ("id", Json::Num(id as f64)),
                        ("worker", Json::Num(worker as f64)),
                        ("k", Json::Num(*k as f64)),
                        ("reused", Json::Bool(*reused)),
                    ]));
                }
                SolveEvent::Harvest { k } => {
                    recycle_k = recycle_k.max(*k);
                    out.push(Json::obj(vec![
                        ("ev", Json::Str("recycle".into())),
                        ("id", Json::Num(id as f64)),
                        ("worker", Json::Num(worker as f64)),
                        ("k", Json::Num(*k as f64)),
                        ("reused", Json::Bool(false)),
                    ]));
                }
                // Start/End are folded into the `solve` summary event.
                SolveEvent::Start { .. } | SolveEvent::End { .. } => {}
            }
        }
        out.push(Json::obj(vec![
            ("ev", Json::Str("solve".into())),
            ("id", Json::Num(id as f64)),
            ("worker", Json::Num(worker as f64)),
            ("engine", Json::Str(engine.into())),
            ("n", Json::Num(n as f64)),
            ("iters", Json::Num(stats.iters as f64)),
            ("seconds", Json::Num(stats.seconds)),
            ("rel_residual", Json::Num(stats.rel_residual)),
            ("stop", Json::Str(stats.stop.label().into())),
            ("recycle_k", Json::Num(recycle_k as f64)),
        ]));
        out
    }

    /// Build a `span` event.
    pub fn span_event(span: &crate::obs::span::SpanRecord) -> Json {
        Json::obj(vec![
            ("ev", Json::Str("span".into())),
            ("name", Json::Str(span.name.clone())),
            (
                "worker",
                span.worker.map_or(Json::Null, |w| Json::Num(w as f64)),
            ),
            ("start", Json::Num(span.start)),
            ("seconds", Json::Num(span.seconds)),
        ])
    }

    /// Build a `worker` rollup event.
    pub fn worker_event(
        worker: usize,
        systems: usize,
        busy_seconds: f64,
        wall_seconds: f64,
        backpressure_seconds: f64,
    ) -> Json {
        let util = if wall_seconds > 0.0 { busy_seconds / wall_seconds } else { 0.0 };
        Json::obj(vec![
            ("ev", Json::Str("worker".into())),
            ("worker", Json::Num(worker as f64)),
            ("systems", Json::Num(systems as f64)),
            ("busy_seconds", Json::Num(busy_seconds)),
            ("wall_seconds", Json::Num(wall_seconds)),
            ("backpressure_seconds", Json::Num(backpressure_seconds)),
            ("utilization", Json::Num(util)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::stats::StopReason;

    #[test]
    fn writes_parseable_jsonl() {
        let path = std::env::temp_dir().join(format!("skr_sink_{}.jsonl", std::process::id()));
        let sink = TraceSink::create(&path).unwrap();
        sink.emit(&Json::obj(vec![("ev", Json::Str("meta".into())), ("count", Json::Num(2.0))]));
        let stats = SolveStats {
            iters: 42,
            seconds: 0.5,
            rel_residual: 1e-9,
            stop: StopReason::Converged,
            trace: vec![],
        };
        let evs = TraceSink::solve_events(
            7,
            0,
            "SKR",
            100,
            &stats,
            &[
                SolveEvent::Start { n: 100, rel: 1.0 },
                SolveEvent::Recycle { k: 5, reused: true },
                SolveEvent::Cycle { iters: 30, rel: 1e-4 },
                SolveEvent::End { iters: 42, seconds: 0.5, rel_residual: 1e-9, stop: "converged" },
            ],
        );
        sink.emit_all(&evs);
        sink.flush();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4); // meta + recycle + cycle + solve
        for line in &lines {
            Json::parse(line).unwrap();
        }
        let solve = Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(solve.get("ev").unwrap().as_str(), Some("solve"));
        assert_eq!(solve.get("iters").unwrap().as_usize(), Some(42));
        assert_eq!(solve.get("recycle_k").unwrap().as_usize(), Some(5));
        assert_eq!(solve.get("stop").unwrap().as_str(), Some("converged"));
        let _ = std::fs::remove_file(&path);
    }
}
