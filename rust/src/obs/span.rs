//! Hierarchical wall-clock spans for pipeline stages.
//!
//! A [`Recorder`] holds one monotonic epoch for the whole run; every
//! [`SpanRecord`] stores its start offset and duration relative to that
//! epoch, so spans from different worker threads land on one comparable
//! timeline. Hierarchy is by `/`-separated names (`solve/w2/sys17` nests
//! under `solve/w2` under `solve`), which keeps the API a single method
//! instead of a tree of guards.

use std::sync::Mutex;
use std::time::Instant;

/// One completed span on the run timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// `/`-separated hierarchical name (e.g. `solve/w0/sys3`).
    pub name: String,
    /// Worker index for per-worker spans (None for pipeline-level stages).
    pub worker: Option<usize>,
    /// Start offset in seconds since the recorder's epoch.
    pub start: f64,
    /// Duration in seconds.
    pub seconds: f64,
}

impl SpanRecord {
    /// Depth in the span hierarchy (0 for top-level stages).
    pub fn depth(&self) -> usize {
        self.name.matches('/').count()
    }

    /// The first path segment (the top-level stage this span belongs to).
    pub fn stage(&self) -> &str {
        self.name.split('/').next().unwrap_or(&self.name)
    }
}

/// Thread-safe collector of [`SpanRecord`]s sharing one epoch.
pub struct Recorder {
    epoch: Instant,
    spans: Mutex<Vec<SpanRecord>>,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder { epoch: Instant::now(), spans: Mutex::new(Vec::new()) }
    }

    /// Seconds since the recorder's epoch (the run timeline coordinate).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record a completed span directly (for callers that timed it
    /// themselves via [`Recorder::now`]).
    pub fn record(&self, name: &str, worker: Option<usize>, start: f64, seconds: f64) {
        let rec = SpanRecord { name: name.to_string(), worker, start, seconds };
        self.spans.lock().expect("span lock poisoned").push(rec);
    }

    /// Open a guard span: records itself on drop (or explicit [`Span::end`]).
    pub fn span(&self, name: &str) -> Span<'_> {
        self.span_for(name, None)
    }

    /// Open a guard span attributed to a worker thread.
    pub fn span_for(&self, name: &str, worker: Option<usize>) -> Span<'_> {
        Span { rec: self, name: name.to_string(), worker, start: self.now() }
    }

    /// Snapshot of everything recorded so far, sorted by start time.
    pub fn spans(&self) -> Vec<SpanRecord> {
        let mut v = self.spans.lock().expect("span lock poisoned").clone();
        v.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap_or(std::cmp::Ordering::Equal));
        v
    }

    /// Total seconds attributed to a stage (summed over matching spans at
    /// the given exact name).
    pub fn total(&self, name: &str) -> f64 {
        self.spans
            .lock()
            .expect("span lock poisoned")
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.seconds)
            .sum()
    }
}

/// RAII guard for an open span.
pub struct Span<'a> {
    rec: &'a Recorder,
    name: String,
    worker: Option<usize>,
    start: f64,
}

impl Span<'_> {
    /// Close the span now (equivalent to dropping it).
    pub fn end(self) {}
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let dur = self.rec.now() - self.start;
        self.rec.record(&self.name, self.worker, self.start, dur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_nested_spans_on_one_timeline() {
        let rec = Recorder::new();
        {
            let _outer = rec.span("solve");
            let inner = rec.span_for("solve/w0", Some(0));
            inner.end();
        }
        let spans = rec.spans();
        assert_eq!(spans.len(), 2);
        // Sorted by start: outer opened first.
        assert_eq!(spans[0].name, "solve");
        assert_eq!(spans[1].name, "solve/w0");
        assert_eq!(spans[1].worker, Some(0));
        assert_eq!(spans[0].depth(), 0);
        assert_eq!(spans[1].depth(), 1);
        assert_eq!(spans[1].stage(), "solve");
        // The inner span starts no earlier and ends no later than the outer.
        assert!(spans[1].start >= spans[0].start);
        assert!(spans[1].start + spans[1].seconds <= spans[0].start + spans[0].seconds + 1e-9);
    }

    #[test]
    fn manual_record_and_totals() {
        let rec = Recorder::new();
        rec.record("gen", None, 0.0, 0.5);
        rec.record("gen", None, 1.0, 0.25);
        rec.record("sort", None, 2.0, 0.125);
        assert!((rec.total("gen") - 0.75).abs() < 1e-12);
        assert!((rec.total("sort") - 0.125).abs() < 1e-12);
        assert_eq!(rec.total("missing"), 0.0);
    }

    #[test]
    fn recorder_is_shareable_across_threads() {
        let rec = Recorder::new();
        std::thread::scope(|s| {
            for w in 0..4 {
                let rec = &rec;
                s.spawn(move || {
                    let sp = rec.span_for(&format!("solve/w{w}"), Some(w));
                    sp.end();
                });
            }
        });
        assert_eq!(rec.spans().len(), 4);
    }
}
