//! Observability layer — structured tracing and metrics for the pipeline
//! and the solvers, in the repo's std-only style (no external deps):
//!
//! * [`span`] — a lightweight hierarchical [`Recorder`]/[`Span`] API that
//!   times pipeline stages (gen → sort → shard → per-worker → per-system).
//! * [`observe`] — the [`SolveObserver`] trait threaded through `gmres` and
//!   `gcrodr`: iteration-level events (cycle residuals, restarts, recycle
//!   harvests) with a zero-cost no-op default, so the solver hot loop is
//!   untouched when tracing is off.
//! * [`sink`] — a thread-safe JSONL event sink behind `--trace-out`.
//! * [`hist`] — fixed-bucket [`Histogram`]s with Prometheus text output,
//!   folded into `RunMetrics` (iterations, solve seconds, δ).
//! * [`progress`] — the opt-in live progress line for `skr generate`.
//! * [`report`] — the `skr report <trace.jsonl>` aggregator producing the
//!   paper-style summary (percentile solve times, iteration histogram,
//!   per-worker timeline, backpressure totals).

pub mod hist;
pub mod observe;
pub mod progress;
pub mod report;
pub mod sink;
pub mod span;

pub use hist::Histogram;
pub use observe::{NoopObserver, RecordingObserver, SolveEvent, SolveObserver};
pub use progress::Progress;
pub use report::TraceReport;
pub use sink::TraceSink;
pub use span::{Recorder, Span, SpanRecord};
