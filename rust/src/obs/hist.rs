//! Fixed-bucket histograms with Prometheus text exposition.
//!
//! Buckets are upper-bound inclusive (`v <= bound`), with an implicit +Inf
//! overflow bucket — exactly Prometheus `le` semantics, so the text
//! snapshot is scrape-compatible. Bucket layouts are fixed per metric
//! (iterations, solve seconds, δ), which makes cross-worker merges exact.

use std::fmt::Write as _;

/// A monotone fixed-bucket histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Ascending finite upper bounds; the +Inf bucket is implicit.
    bounds: Vec<f64>,
    /// Per-bucket counts, `bounds.len() + 1` entries (last = overflow).
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    /// 1-2-5 decades covering iteration counts up to the paper's 10⁴ cap.
    pub fn iters_buckets() -> Histogram {
        Histogram::new(&[
            1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0, 2000.0, 5000.0, 10_000.0,
        ])
    }

    /// Log-decade buckets for per-system solve seconds (100 µs … 1000 s).
    pub fn seconds_buckets() -> Histogram {
        Histogram::new(&[
            1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0, 100.0, 300.0,
            1000.0,
        ])
    }

    /// Uniform buckets over [0, 1] for the δ subspace distance.
    pub fn unit_buckets() -> Histogram {
        Histogram::new(&[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0])
    }

    pub fn observe(&mut self, v: f64) {
        // partition_point: first bucket whose bound admits v.
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Merge a same-layout histogram (multi-worker reduction).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "histogram bucket layouts differ");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile estimate from bucket counts: returns the upper bound of the
    /// bucket containing the q-quantile (+Inf bucket reports the largest
    /// finite bound). `q` is clamped to [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap_or(&f64::INFINITY)
                };
            }
        }
        *self.bounds.last().unwrap_or(&f64::INFINITY)
    }

    /// Prometheus text-format exposition (cumulative `le` buckets).
    pub fn prometheus(&self, name: &str, out: &mut String) {
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if i < self.bounds.len() {
                let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", self.bounds[i]);
            } else {
                let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            }
        }
        let _ = writeln!(out, "{name}_sum {}", self.sum);
        let _ = writeln!(out, "{name}_count {}", self.count);
    }

    /// Compact ASCII rendering for terminal reports (non-empty buckets only).
    pub fn render(&self, label: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{label} (n={}, mean={:.4})", self.count, self.mean());
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1);
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
            let hi =
                if i < self.bounds.len() { format!("{}", self.bounds[i]) } else { "inf".into() };
            let bar = "#".repeat(((c * 40) / max).max(1) as usize);
            let _ = writeln!(out, "  ({lo:>9.4}, {hi:>9}] {c:>7}  {bar}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observes_into_correct_buckets() {
        let mut h = Histogram::new(&[1.0, 10.0, 100.0]);
        h.observe(0.5); // bucket 0 (le 1)
        h.observe(1.0); // bucket 0 (le is inclusive)
        h.observe(5.0); // bucket 1
        h.observe(1000.0); // overflow
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 1006.5).abs() < 1e-12);
        let mut text = String::new();
        h.prometheus("skr_test", &mut text);
        assert!(text.contains("skr_test_bucket{le=\"1\"} 2"));
        assert!(text.contains("skr_test_bucket{le=\"10\"} 3"));
        assert!(text.contains("skr_test_bucket{le=\"100\"} 3"));
        assert!(text.contains("skr_test_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("skr_test_count 4"));
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::iters_buckets();
        let mut b = Histogram::iters_buckets();
        a.observe(3.0);
        b.observe(30.0);
        b.observe(3000.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.sum() - 3033.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles_bracket_the_data() {
        let mut h = Histogram::seconds_buckets();
        for _ in 0..90 {
            h.observe(0.002);
        }
        for _ in 0..10 {
            h.observe(0.5);
        }
        // p50 lands in the 3e-3 bucket, p99 in the 1.0 bucket.
        assert!((h.quantile(0.5) - 3e-3).abs() < 1e-12);
        assert!((h.quantile(0.99) - 1.0).abs() < 1e-12);
        assert!(h.quantile(0.0) > 0.0);
    }

    #[test]
    fn empty_histogram_is_harmless() {
        let h = Histogram::unit_buckets();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        let mut s = String::new();
        h.prometheus("skr_delta", &mut s);
        assert!(s.contains("skr_delta_count 0"));
    }
}
