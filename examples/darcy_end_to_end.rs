//! End-to-end driver: the full three-layer system on a real small workload.
//!
//! 1. **L3 (rust)** generates a Darcy-flow dataset with the SKR pipeline
//!    (sorting + GCRO-DR recycling across systems, multithreaded), and the
//!    same dataset with the GMRES baseline for reference.
//! 2. **Runtime** loads the AOT-compiled FNO (L2 jax model wrapping the L1
//!    Pallas spectral kernel, lowered to HLO by `make artifacts`).
//! 3. The FNO is trained on both datasets for a few hundred Adam steps; the
//!    loss curves and final test errors are reported — the paper's Table 33
//!    dataset-validity experiment, end to end.
//!
//! ```bash
//! make artifacts && cargo run --release --example darcy_end_to_end
//! # faster/slower: --count 96 --steps 150 --n 1024
//! ```

#![allow(clippy::field_reassign_with_default)]
use skr::coordinator::{Pipeline, PipelineConfig, SortStrategy};
use skr::no::{FnoDataset, Trainer};
use skr::pde::FamilyKind;
use skr::precond::PrecondKind;
use skr::runtime::{FnoRuntime, Manifest};
use skr::solver::Engine;
use skr::util::args::Args;
use skr::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let count = args.num_or("count", 160usize);
    let unknowns = args.num_or("n", 1024usize);
    let steps = args.num_or("steps", 200usize);

    let art_dir = Manifest::default_dir();
    anyhow::ensure!(
        art_dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    println!("=== Stage 1: data generation (L3 pipeline) ===");
    let mut results = Vec::new();
    for (label, engine, sort) in [
        ("GMRES", Engine::Gmres, SortStrategy::None),
        ("SKR", Engine::SkrRecycle, SortStrategy::Greedy),
    ] {
        let dir = std::path::PathBuf::from(format!("results/e2e_darcy_{}", label.to_lowercase()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = PipelineConfig::default();
        cfg.family = FamilyKind::Darcy;
        cfg.unknowns = unknowns;
        cfg.count = count;
        cfg.engine = engine;
        cfg.sort = sort;
        cfg.precond = PrecondKind::Jacobi;
        cfg.solver.tol = 1e-8;
        cfg.threads = std::thread::available_parallelism().map(|p| p.get().min(8)).unwrap_or(2);
        cfg.out_dir = Some(dir.clone());
        let t = Timer::start();
        let r = Pipeline::new(cfg).run()?;
        println!(
            "  {label:<6}: {count} systems of n={unknowns} in {:.2}s wall \
             ({:.1} iters/system, {} max-iter hits)",
            t.secs(),
            r.metrics.mean_iters(),
            r.metrics.max_iter_hits
        );
        results.push((label, dir, r.metrics.solve_seconds));
    }
    println!(
        "  => generation speedup (GMRES/SKR solve time): {:.2}x\n",
        results[0].2 / results[1].2
    );

    println!("=== Stage 2+3: FNO training through PJRT (L2+L1 via HLO) ===");
    let mut finals = Vec::new();
    for (label, dir, _) in &results {
        let mut fno = FnoRuntime::load(&art_dir)?;
        let ds = FnoDataset::load(dir, fno.manifest.grid, 0.2, 7)?;
        println!(
            "  {label:<6}: training FNO ({} weights) on {} samples, {} steps ...",
            fno.manifest.num_weights(),
            ds.count,
            steps
        );
        let trainer = Trainer { steps, eval_every: (steps / 5).max(1), seed: 11, log: false };
        let rep = trainer.train(&mut fno, &ds)?;
        print!("    loss curve:");
        for (s, l) in rep.losses.iter().step_by((steps / 8).max(1)) {
            print!("  {s}:{l:.3}");
        }
        println!();
        println!(
            "    test rel-L2 at evals: {:?}  ({:.1}s)",
            rep.test_curve.iter().map(|(s, e)| format!("{s}:{e:.4}")).collect::<Vec<_>>(),
            rep.seconds
        );
        finals.push((label.to_string(), rep.final_test_rel_l2));
    }

    println!("\n=== Verdict (paper Table 33) ===");
    let (g, s) = (finals[0].1, finals[1].1);
    println!("  FNO trained on GMRES data: test rel-L2 {g:.4}");
    println!("  FNO trained on SKR   data: test rel-L2 {s:.4}");
    let gap = (g - s).abs() / g.max(s).max(1e-12);
    println!(
        "  relative gap {:.1}% — {}",
        gap * 100.0,
        if gap < 0.15 { "datasets are training-equivalent ✓" } else { "UNEXPECTED divergence ✗" }
    );
    Ok(())
}
