//! Helmholtz tolerance sweep — the paper's hardest family. Reproduces the
//! shape of Tables 24–30: the SKR advantage *grows* as the tolerance
//! tightens, and GMRES starts hitting the iteration cap while SKR does not
//! (the stability story of Fig. 13).
//!
//! ```bash
//! cargo run --release --example helmholtz_sweep -- --n 2500 --count 24
//! ```

#![allow(clippy::field_reassign_with_default)]
use skr::coordinator::PipelineConfig;
use skr::harness::compare::run_pair;
use skr::pde::FamilyKind;
use skr::precond::PrecondKind;
use skr::util::args::Args;
use skr::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.num_or("n", 1600usize);
    let count = args.num_or("count", 16usize);

    let mut table = Table::new(
        &format!("Helmholtz n={n}, SOR preconditioner — GMRES vs SKR across tolerances"),
        &["tol", "GMRES s/sys", "SKR s/sys", "GMRES iters", "SKR iters", "time x", "iters x", "GMRES cap-hits"],
    );

    for tol in [1e-2, 1e-4, 1e-6] {
        let mut cfg = PipelineConfig::default();
        cfg.family = FamilyKind::Helmholtz;
        cfg.unknowns = n;
        cfg.count = count;
        cfg.precond = PrecondKind::Sor;
        cfg.solver.tol = tol;
        cfg.threads = 1;
        let (gm, skr) = run_pair(&cfg)?;
        table.row(vec![
            format!("{tol:.0e}"),
            format!("{:.4}", gm.mean_time()),
            format!("{:.4}", skr.mean_time()),
            format!("{:.0}", gm.mean_iters()),
            format!("{:.0}", skr.mean_iters()),
            format!("{:.2}", gm.mean_time() / skr.mean_time()),
            format!("{:.2}", gm.mean_iters() / skr.mean_iters()),
            format!("{}", gm.max_iter_hits),
        ]);
    }
    print!("{}", table.render());
    table.write_csv(std::path::Path::new("results/helmholtz_sweep.csv"))?;
    println!("\nCSV → results/helmholtz_sweep.csv");
    Ok(())
}
