//! Quickstart: generate a small Darcy-flow dataset with the SKR pipeline,
//! compare against the GMRES baseline, and export `.npy` files.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

#![allow(clippy::field_reassign_with_default)]
use skr::coordinator::{Pipeline, PipelineConfig, SortStrategy};
use skr::pde::FamilyKind;
use skr::precond::PrecondKind;
use skr::solver::Engine;

fn main() -> anyhow::Result<()> {
    // 64 Darcy problems on a 40×40 grid (1600 unknowns each), solved to 1e-8.
    let mut cfg = PipelineConfig::default();
    cfg.family = FamilyKind::Darcy;
    cfg.unknowns = 1600;
    cfg.count = 64;
    cfg.precond = PrecondKind::Jacobi;
    cfg.solver.tol = 1e-8;
    cfg.threads = 2;
    cfg.out_dir = Some("results/quickstart_darcy".into());

    // --- SKR: sort by parameter similarity, recycle Krylov subspaces -----
    cfg.engine = Engine::SkrRecycle;
    cfg.sort = SortStrategy::Greedy;
    let skr = Pipeline::new(cfg.clone()).run()?;

    // --- baseline: independent GMRES in stream order ---------------------
    cfg.engine = Engine::Gmres;
    cfg.sort = SortStrategy::None;
    cfg.out_dir = None; // dataset contents are identical; skip re-export
    let gmres = Pipeline::new(cfg).run()?;

    println!("Darcy flow, 64 systems @ 1600 unknowns, Jacobi preconditioner, tol 1e-8\n");
    println!(
        "  GMRES : {:>8.4}s/system  {:>8.1} iters/system",
        gmres.metrics.mean_time(),
        gmres.metrics.mean_iters()
    );
    println!(
        "  SKR   : {:>8.4}s/system  {:>8.1} iters/system",
        skr.metrics.mean_time(),
        skr.metrics.mean_iters()
    );
    println!(
        "\n  speedup: {:.2}x wall time, {:.2}x iterations",
        gmres.metrics.mean_time() / skr.metrics.mean_time(),
        gmres.metrics.mean_iters() / skr.metrics.mean_iters()
    );
    if let Some(ds) = &skr.dataset {
        println!(
            "\n  dataset: {}  (inputs.npy [{}x{}], solutions.npy [{}x{}])",
            ds.dir.display(),
            ds.count,
            ds.input_dim,
            ds.count,
            ds.sol_dim
        );
        println!("  load it from python:  np.load('{}/solutions.npy')", ds.dir.display());
    }
    Ok(())
}
