//! Thermal FEM walkthrough: the unstructured-mesh code path. Builds the
//! irregular annular-sector mesh, assembles the P1 Laplace system, solves a
//! sequence of random-boundary problems with recycling, and verifies the
//! discrete maximum principle on every solution.
//!
//! ```bash
//! cargo run --release --example thermal_fem
//! ```

use skr::pde::thermal::ThermalFamily;
use skr::pde::{generate, ProblemFamily};
use skr::precond::PrecondKind;
use skr::solver::{solve_sequence, Engine, SolverConfig};

fn main() -> anyhow::Result<()> {
    let fam = ThermalFamily::new(24, 96); // ~2k unknowns, wavy outer boundary
    let mesh = fam.mesh();
    println!(
        "mesh: {} nodes, {} triangles, {} interior unknowns",
        mesh.num_nodes(),
        mesh.tris.len(),
        fam.num_unknowns()
    );

    let count = 24;
    let systems = generate(&fam, count, 42)?;
    println!(
        "generated {count} problems; boundary temps range over inner [-100,0] / outer [0,100]"
    );

    let cfg = SolverConfig::default().with_tol(1e-10);
    for engine in [Engine::Gmres, Engine::SkrRecycle] {
        let t = std::time::Instant::now();
        let out = solve_sequence(&systems, engine, PrecondKind::BJacobi, &cfg)?;
        let secs = t.elapsed().as_secs_f64();
        let iters: usize = out.iter().map(|(_, s)| s.iters).sum();

        // Physics check: every temperature field obeys the maximum principle.
        for (i, (x, stats)) in out.iter().enumerate() {
            assert!(stats.converged(), "system {i} did not converge");
            let (tin, tout) = (systems[i].params[0], systems[i].params[1]);
            for &v in x {
                assert!(
                    v >= tin - 1e-6 && v <= tout + 1e-6,
                    "max principle violated: {v} outside [{tin}, {tout}]"
                );
            }
        }
        println!(
            "  {:<6}: {:.2}s total, {} iters total — all {} solutions within boundary bounds ✓",
            engine.label(),
            secs,
            iters,
            count
        );
    }
    Ok(())
}
