"""L1 kernel correctness: the Pallas spectral convolution against the
pure-jnp oracle, with hypothesis sweeping shapes and value scales."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import spectral_conv_complex_ref, spectral_conv_ref
from compile.kernels.spectral_conv import spectral_conv


def rand(key, shape, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(jnp.float32)


def make_case(b, kx, ky, cin, cout, seed, scale=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    xr = rand(keys[0], (b, kx, ky, cin), scale)
    xi = rand(keys[1], (b, kx, ky, cin), scale)
    wr = rand(keys[2], (kx, ky, cin, cout), scale)
    wi = rand(keys[3], (kx, ky, cin, cout), scale)
    return xr, xi, wr, wi


def test_matches_ref_basic():
    xr, xi, wr, wi = make_case(2, 4, 3, 5, 6, seed=0)
    got_r, got_i = spectral_conv(xr, xi, wr, wi)
    want_r, want_i = spectral_conv_ref(xr, xi, wr, wi)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-5)


def test_ref_matches_complex_ref():
    xr, xi, wr, wi = make_case(3, 2, 2, 4, 4, seed=1)
    r, i = spectral_conv_ref(xr, xi, wr, wi)
    c = spectral_conv_complex_ref(xr + 1j * xi, wr + 1j * wi)
    np.testing.assert_allclose(r, jnp.real(c), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(i, jnp.imag(c), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    kx=st.integers(1, 6),
    ky=st.integers(1, 5),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_matches_ref_hypothesis(b, kx, ky, cin, cout, seed, scale):
    xr, xi, wr, wi = make_case(b, kx, ky, cin, cout, seed=seed, scale=scale)
    got_r, got_i = spectral_conv(xr, xi, wr, wi)
    want_r, want_i = spectral_conv_ref(xr, xi, wr, wi)
    tol = 2e-4 * max(scale * scale, 1.0)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-4, atol=tol)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-4, atol=tol)


def test_gradients_match_ref():
    """custom_vjp backward == autodiff through the jnp reference."""
    xr, xi, wr, wi = make_case(2, 3, 2, 4, 5, seed=3)

    def loss_kernel(xr, xi, wr, wi):
        r, i = spectral_conv(xr, xi, wr, wi)
        return jnp.sum(r * r) + jnp.sum(jnp.sin(i))

    def loss_ref(xr, xi, wr, wi):
        r, i = spectral_conv_ref(xr, xi, wr, wi)
        return jnp.sum(r * r) + jnp.sum(jnp.sin(i))

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(xr, xi, wr, wi)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(xr, xi, wr, wi)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_linearity_in_x():
    xr, xi, wr, wi = make_case(1, 2, 2, 3, 3, seed=4)
    r1, i1 = spectral_conv(xr, xi, wr, wi)
    r2, i2 = spectral_conv(2.0 * xr, 2.0 * xi, wr, wi)
    np.testing.assert_allclose(r2, 2.0 * r1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(i2, 2.0 * i1, rtol=1e-5, atol=1e-5)


def test_zero_weights_give_zero():
    xr, xi, wr, wi = make_case(2, 2, 2, 3, 4, seed=5)
    r, i = spectral_conv(xr, xi, jnp.zeros_like(wr), jnp.zeros_like(wi))
    assert float(jnp.abs(r).max()) == 0.0
    assert float(jnp.abs(i).max()) == 0.0


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dtype_preserved(dtype):
    xr, xi, wr, wi = make_case(1, 2, 2, 2, 2, seed=6)
    r, i = spectral_conv(xr.astype(dtype), xi.astype(dtype), wr.astype(dtype), wi.astype(dtype))
    assert r.dtype == dtype and i.dtype == dtype
