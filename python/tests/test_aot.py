"""AOT artifact tests: the HLO text artifacts exist, parse as HLO modules,
and the manifest is structurally sound and consistent with the params."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _need_artifacts():
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        pytest.skip("artifacts not built (run `make artifacts`)")


def test_manifest_structure():
    _need_artifacts()
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    assert set(m["artifacts"]) == {"forward", "train_step"}
    cfg = m["config"]
    for key in ("grid", "batch", "width", "modes", "layers"):
        assert cfg[key] > 0
    names = [p["name"] for p in m["params"]]
    assert names[0] == "lift_w" and names[-1] == "proj2_b"
    sig = m["signature"]
    n = len(names)
    assert len(sig["train_step_inputs"]) == 3 * n + 3
    assert len(sig["train_step_outputs"]) == 3 * n + 2


def test_hlo_text_is_hlo():
    _need_artifacts()
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    for kind in ("forward", "train_step"):
        path = os.path.join(ART, m["artifacts"][kind])
        text = open(path).read()
        assert text.startswith("HloModule"), f"{kind} is not HLO text"
        assert "ENTRY" in text
        # fft must have survived lowering (the FNO core).
        assert "fft" in text.lower(), f"{kind} lost the FFT"


def test_param_files_match_manifest():
    _need_artifacts()
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    for p in m["params"]:
        arr = np.load(os.path.join(ART, "params", p["name"] + ".npy"))
        assert list(arr.shape) == p["shape"], p["name"]
        assert arr.dtype == np.float32
        assert np.isfinite(arr).all(), p["name"]


def test_rust_npy_interchange(tmp_path):
    """Arrays written by numpy are read back identically — the same format
    rust util::npy consumes/produces (cross-language contract)."""
    a = np.arange(12, dtype=np.float64).reshape(3, 4) * 0.5
    np.save(tmp_path / "x.npy", a)
    b = np.load(tmp_path / "x.npy")
    np.testing.assert_array_equal(a, b)
