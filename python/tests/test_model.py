"""L2 model tests: FNO shapes, loss behaviour, and that the Adam train step
actually reduces the loss on a learnable synthetic task."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    FnoConfig,
    _nparams,
    adam_train_step,
    forward,
    forward_fn,
    init_params,
    param_arrays,
    relative_l2,
)

CFG = FnoConfig(grid=16, batch=4, width=8, modes=4, layers=2, proj=16)


def params_for(cfg, seed=0):
    return param_arrays(init_params(cfg, jax.random.PRNGKey(seed)))


def test_forward_shape():
    arrays = params_for(CFG)
    x = jnp.ones((CFG.batch, CFG.grid, CFG.grid, 1), jnp.float32)
    y = forward(CFG, arrays, x)
    assert y.shape == (CFG.batch, CFG.grid, CFG.grid, 1)
    assert bool(jnp.isfinite(y).all())


def test_nparams_matches_init():
    arrays = params_for(CFG)
    assert len(arrays) == _nparams(CFG)


def test_relative_l2_properties():
    y = jnp.ones((2, 4, 4, 1))
    assert float(relative_l2(y, y)) < 1e-6
    assert float(relative_l2(2.0 * y, y)) > 0.5


def test_forward_fn_tuple_abi():
    arrays = params_for(CFG)
    x = jnp.zeros((CFG.batch, CFG.grid, CFG.grid, 1), jnp.float32)
    out = forward_fn(CFG)(*arrays, x)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == x.shape


def test_train_step_reduces_loss():
    cfg = CFG
    arrays = params_for(cfg, seed=1)
    step_fn = jax.jit(adam_train_step(cfg, lr=5e-3))

    # Learnable synthetic operator: y = smoothed(x) (low-pass), well inside
    # FNO's hypothesis class.
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (cfg.batch, cfg.grid, cfg.grid, 1)).astype(jnp.float32)
    xf = jnp.fft.rfft2(x, axes=(1, 2))
    mask = jnp.zeros_like(xf)
    mask = mask.at[:, :3, :3, :].set(1.0)
    y = jnp.fft.irfft2(xf * mask, s=(cfg.grid, cfg.grid), axes=(1, 2)).astype(jnp.float32)

    n = _nparams(cfg)
    m = [jnp.zeros_like(a) for a in arrays]
    v = [jnp.zeros_like(a) for a in arrays]
    step = jnp.zeros((), jnp.float32)

    losses = []
    state = list(arrays) + m + v + [step]
    for _ in range(60):
        out = step_fn(*state, x, y)
        state = list(out[: 3 * n]) + [out[3 * n]]
        losses.append(float(out[3 * n + 1]))

    assert all(np.isfinite(losses)), losses
    assert losses[-1] < 0.6 * losses[0], f"no learning: {losses[0]} -> {losses[-1]}"


def test_train_step_count_increments():
    cfg = CFG
    arrays = params_for(cfg, seed=3)
    step_fn = jax.jit(adam_train_step(cfg))
    n = _nparams(cfg)
    m = [jnp.zeros_like(a) for a in arrays]
    v = [jnp.zeros_like(a) for a in arrays]
    x = jnp.zeros((cfg.batch, cfg.grid, cfg.grid, 1), jnp.float32)
    out = step_fn(*(list(arrays) + m + v + [jnp.zeros((), jnp.float32)]), x, x)
    assert float(out[3 * n]) == 1.0
