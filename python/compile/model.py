"""L2: FNO-2d in JAX — forward pass, relative-L2 loss and an Adam train
step, all built on the L1 Pallas spectral-conv kernel so the whole model
lowers into a single HLO module for the rust runtime.

Parameters are a flat ``[(name, array), ...]`` list in a FIXED order — the
rust side addresses buffers positionally via ``manifest.json``.
"""

import jax
import jax.numpy as jnp

from .kernels.spectral_conv import spectral_conv


# ---------------------------------------------------------------- config


class FnoConfig:
    """Architecture hyper-parameters (baked into the AOT artifact)."""

    def __init__(self, grid=32, batch=8, width=24, modes=8, layers=3, proj=64):
        self.grid = grid
        self.batch = batch
        self.width = width
        self.modes = modes
        self.layers = layers
        self.proj = proj

    def to_dict(self):
        return {
            "grid": self.grid,
            "batch": self.batch,
            "width": self.width,
            "modes": self.modes,
            "layers": self.layers,
            "proj": self.proj,
        }


# ---------------------------------------------------------------- params


def init_params(cfg, key):
    """Initialize the flat parameter list (order is the ABI)."""
    params = []
    k = iter(jax.random.split(key, 4 + 6 * cfg.layers))

    def glorot(key, shape, fan_in, fan_out):
        s = jnp.sqrt(2.0 / (fan_in + fan_out))
        return (jax.random.normal(key, shape) * s).astype(jnp.float32)

    params.append(("lift_w", glorot(next(k), (1, cfg.width), 1, cfg.width)))
    params.append(("lift_b", jnp.zeros((cfg.width,), jnp.float32)))
    for l in range(cfg.layers):
        m, w = cfg.modes, cfg.width
        scale = 1.0 / (w * w)
        params.append(
            (f"spec{l}_wr", (jax.random.normal(next(k), (2 * m, m, w, w)) * scale).astype(jnp.float32))
        )
        params.append(
            (f"spec{l}_wi", (jax.random.normal(next(k), (2 * m, m, w, w)) * scale).astype(jnp.float32))
        )
        params.append((f"byp{l}_w", glorot(next(k), (w, w), w, w)))
        params.append((f"byp{l}_b", jnp.zeros((w,), jnp.float32)))
    params.append(("proj1_w", glorot(next(k), (cfg.width, cfg.proj), cfg.width, cfg.proj)))
    params.append(("proj1_b", jnp.zeros((cfg.proj,), jnp.float32)))
    params.append(("proj2_w", glorot(next(k), (cfg.proj, 1), cfg.proj, 1)))
    params.append(("proj2_b", jnp.zeros((1,), jnp.float32)))
    return params


def param_arrays(params):
    return [a for (_, a) in params]


def param_names(params):
    return [n for (n, _) in params]


# ---------------------------------------------------------------- forward


def _spectral_layer(h, wr, wi, modes):
    """One FNO spectral mixing: rfft2 → truncate → per-mode matmul (Pallas)
    → scatter back → irfft2."""
    b, s, _, w = h.shape
    m = modes
    h_hat = jnp.fft.rfft2(h, axes=(1, 2))  # [B, S, S//2+1, W] complex64

    # Keep the two corner blocks in kx (low positive & negative freqs) and
    # the lowest m in ky; stack to [B, 2m, m, W].
    top = h_hat[:, :m, :m, :]
    bot = h_hat[:, -m:, :m, :]
    x = jnp.concatenate([top, bot], axis=1)
    or_, oi = spectral_conv(
        jnp.real(x).astype(jnp.float32),
        jnp.imag(x).astype(jnp.float32),
        wr,
        wi,
    )
    out = or_ + 1j * oi

    zeros = jnp.zeros_like(h_hat)
    zeros = zeros.at[:, :m, :m, :].set(out[:, :m])
    zeros = zeros.at[:, -m:, :m, :].set(out[:, m:])
    return jnp.fft.irfft2(zeros, s=(s, s), axes=(1, 2)).astype(jnp.float32)


def forward(cfg, arrays, x):
    """FNO forward: x [B, S, S, 1] → prediction [B, S, S, 1].

    `arrays` is the positional parameter list from ``param_arrays``.
    """
    it = iter(arrays)
    lift_w, lift_b = next(it), next(it)
    h = x @ lift_w + lift_b  # [B,S,S,W]
    for _ in range(cfg.layers):
        wr, wi, byp_w, byp_b = next(it), next(it), next(it), next(it)
        spec = _spectral_layer(h, wr, wi, cfg.modes)
        lin = h @ byp_w + byp_b
        h = jax.nn.gelu(spec + lin)
    p1w, p1b, p2w, p2b = next(it), next(it), next(it), next(it)
    h = jax.nn.gelu(h @ p1w + p1b)
    return h @ p2w + p2b


def relative_l2(pred, target):
    """Mean relative L2 error over the batch (the FNO community metric)."""
    diff = jnp.sqrt(jnp.sum((pred - target) ** 2, axis=(1, 2, 3)))
    norm = jnp.sqrt(jnp.sum(target**2, axis=(1, 2, 3))) + 1e-8
    return jnp.mean(diff / norm)


# ---------------------------------------------------------------- training


def adam_train_step(cfg, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """Build the jittable train step:

      (params..., m..., v..., step, x, y) → (params'..., m'..., v'..., loss)

    All state flows through the signature — the rust runtime owns it.
    """

    def loss_fn(arrays, x, y):
        return relative_l2(forward(cfg, arrays, x), y)

    def step_fn(*args):
        n = _nparams(cfg)
        arrays = list(args[:n])
        m_state = list(args[n : 2 * n])
        v_state = list(args[2 * n : 3 * n])
        step = args[3 * n]
        x, y = args[3 * n + 1], args[3 * n + 2]

        loss, grads = jax.value_and_grad(loss_fn)(arrays, x, y)
        step = step + 1.0
        outs = []
        new_m, new_v = [], []
        for a, g, mm, vv in zip(arrays, grads, m_state, v_state):
            mm = b1 * mm + (1.0 - b1) * g
            vv = b2 * vv + (1.0 - b2) * g * g
            mhat = mm / (1.0 - b1**step)
            vhat = vv / (1.0 - b2**step)
            outs.append(a - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mm)
            new_v.append(vv)
        return tuple(outs) + tuple(new_m) + tuple(new_v) + (step, loss)

    return step_fn


def _nparams(cfg):
    return 2 + 4 * cfg.layers + 4


def forward_fn(cfg):
    """Build the jittable inference function (params..., x) → (yhat,)."""

    def fn(*args):
        n = _nparams(cfg)
        arrays = list(args[:n])
        x = args[n]
        return (forward(cfg, arrays, x),)

    return fn
