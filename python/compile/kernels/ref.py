"""Pure-jnp oracle for the spectral-convolution kernel.

The FNO spectral layer multiplies each retained Fourier mode's channel
vector by a per-mode complex weight matrix:

    out[b, kx, ky, o] = sum_i  x[b, kx, ky, i] * w[kx, ky, i, o]   (complex)

This file is the correctness reference the Pallas kernel is tested against
(hypothesis sweeps shapes/dtypes in ``python/tests/test_kernel.py``).
"""

import jax.numpy as jnp


def spectral_conv_ref(xr, xi, wr, wi):
    """Complex per-mode channel mixing, split into real/imag planes.

    Args:
      xr, xi: [B, KX, KY, CIN] real/imaginary parts of the truncated modes.
      wr, wi: [KX, KY, CIN, COUT] real/imaginary parts of the mode weights.

    Returns:
      (or_, oi): [B, KX, KY, COUT] real/imaginary outputs.
    """
    xr = jnp.asarray(xr)
    xi = jnp.asarray(xi)
    wr = jnp.asarray(wr)
    wi = jnp.asarray(wi)
    or_ = jnp.einsum("bxyi,xyio->bxyo", xr, wr) - jnp.einsum("bxyi,xyio->bxyo", xi, wi)
    oi = jnp.einsum("bxyi,xyio->bxyo", xr, wi) + jnp.einsum("bxyi,xyio->bxyo", xi, wr)
    return or_, oi


def spectral_conv_complex_ref(x, w):
    """Same contraction in native complex arithmetic (cross-check)."""
    return jnp.einsum("bxyi,xyio->bxyo", x, w)
