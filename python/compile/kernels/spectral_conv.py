"""L1 Pallas kernel: FNO spectral convolution (per-mode complex channel mix).

The kernel tiles the mode plane: grid = (KX, KY); each program instance
loads one mode's activation block ``[B, 1, 1, CIN]`` and weight block
``[1, 1, CIN, COUT]`` into VMEM and performs the four real contractions of a
complex matmul on the MXU. BlockSpec expresses the HBM→VMEM schedule that a
CUDA implementation would write with threadblocks.

TPU sizing note (DESIGN.md §Hardware-Adaptation): with B=8, CIN=COUT=24 the
per-instance VMEM footprint is 2·(8·24 + 24·24 + 8·24) f32 ≈ 7.7 KiB, far
under the ~16 MiB VMEM budget — the BlockSpec could be widened to batch many
modes per instance (see `mode_block`), trading VMEM for fewer grid steps.
On CPU we must run ``interpret=True`` (Mosaic custom-calls cannot execute on
the CPU PJRT plugin), so the kernel is correctness-validated here and
perf-estimated analytically.
"""



import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    # Blocks: x* [B, bx, by, CIN]; w* [bx, by, CIN, COUT].
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    # Four real contractions of the complex product, contracted over CIN.
    rr = jnp.einsum("bxyi,xyio->bxyo", xr, wr)
    ii = jnp.einsum("bxyi,xyio->bxyo", xi, wi)
    ri = jnp.einsum("bxyi,xyio->bxyo", xr, wi)
    ir = jnp.einsum("bxyi,xyio->bxyo", xi, wr)
    or_ref[...] = rr - ii
    oi_ref[...] = ri + ir


def _pallas_forward(xr, xi, wr, wi, mode_block=1):
    """Raw Pallas call (no autodiff rule)."""
    b, kx, ky, cin = xr.shape
    cout = wr.shape[-1]
    assert wr.shape[:2] == (kx, ky), (wr.shape, xr.shape)
    bx = min(mode_block, kx)
    by = min(mode_block, ky)
    assert kx % bx == 0 and ky % by == 0, "mode_block must divide the mode grid"
    grid = (kx // bx, ky // by)

    x_spec = pl.BlockSpec((b, bx, by, cin), lambda i, j: (0, i, j, 0))
    w_spec = pl.BlockSpec((bx, by, cin, cout), lambda i, j: (i, j, 0, 0))
    o_spec = pl.BlockSpec((b, bx, by, cout), lambda i, j: (0, i, j, 0))

    out_shape = [
        jax.ShapeDtypeStruct((b, kx, ky, cout), xr.dtype),
        jax.ShapeDtypeStruct((b, kx, ky, cout), xr.dtype),
    ]
    return tuple(
        pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[x_spec, x_spec, w_spec, w_spec],
            out_specs=[o_spec, o_spec],
            out_shape=out_shape,
            interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
        )(xr, xi, wr, wi)
    )


@jax.custom_vjp
def spectral_conv(xr, xi, wr, wi):
    """Pallas spectral convolution with a custom VJP.

    Interpret-mode ``pallas_call`` does not support reverse-mode autodiff,
    so the backward pass is the analytic transpose of the (real-split)
    complex contraction, written in jnp — it lowers to plain HLO dots.

    Args:
      xr, xi: [B, KX, KY, CIN] retained-mode activations (real/imag).
      wr, wi: [KX, KY, CIN, COUT] mode weights (real/imag).

    Returns:
      (or_, oi): [B, KX, KY, COUT].
    """
    return _pallas_forward(xr, xi, wr, wi)


def _fwd(xr, xi, wr, wi):
    return _pallas_forward(xr, xi, wr, wi), (xr, xi, wr, wi)


def _bwd(res, cot):
    xr, xi, wr, wi = res
    g_or, g_oi = cot
    # Transpose of out_r = xr·wr − xi·wi ; out_i = xr·wi + xi·wr
    d_xr = jnp.einsum("bxyo,xyio->bxyi", g_or, wr) + jnp.einsum("bxyo,xyio->bxyi", g_oi, wi)
    d_xi = jnp.einsum("bxyo,xyio->bxyi", g_oi, wr) - jnp.einsum("bxyo,xyio->bxyi", g_or, wi)
    d_wr = jnp.einsum("bxyi,bxyo->xyio", xr, g_or) + jnp.einsum("bxyi,bxyo->xyio", xi, g_oi)
    d_wi = jnp.einsum("bxyi,bxyo->xyio", xr, g_oi) - jnp.einsum("bxyi,bxyo->xyio", xi, g_or)
    return d_xr, d_xi, d_wr, d_wi


spectral_conv.defvjp(_fwd, _bwd)
