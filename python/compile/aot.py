"""AOT compile path: lower the FNO forward and Adam train step to HLO
*text* and write initial parameters + a manifest for the rust runtime.

HLO text (NOT ``lowered.compile()``/``serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
offline xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import FnoConfig, adam_train_step, forward_fn, init_params, param_arrays, param_names


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_npy_f32(path, arr):
    """Minimal .npy v1.0 writer (float32, C-order) matching rust util::npy."""
    import numpy as np

    np.save(path, np.asarray(arr, dtype=np.float32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--grid", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--width", type=int, default=24)
    ap.add_argument("--modes", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = FnoConfig(
        grid=args.grid,
        batch=args.batch,
        width=args.width,
        modes=args.modes,
        layers=args.layers,
    )
    os.makedirs(args.out, exist_ok=True)
    params_dir = os.path.join(args.out, "params")
    os.makedirs(params_dir, exist_ok=True)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    arrays = param_arrays(params)
    names = param_names(params)

    # --- initial parameters -------------------------------------------------
    param_meta = []
    for name, arr in params:
        write_npy_f32(os.path.join(params_dir, f"{name}.npy"), arr)
        param_meta.append({"name": name, "shape": list(arr.shape)})

    # --- forward artifact ---------------------------------------------------
    x_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.grid, cfg.grid, 1), jnp.float32)
    fwd = forward_fn(cfg)
    fwd_args = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in arrays] + [x_spec]
    fwd_lowered = jax.jit(fwd).lower(*fwd_args)
    fwd_path = os.path.join(args.out, "fno_forward.hlo.txt")
    with open(fwd_path, "w") as f:
        f.write(to_hlo_text(fwd_lowered))

    # --- train-step artifact -------------------------------------------------
    step_fn = adam_train_step(cfg, lr=args.lr)
    zeros_like = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in arrays]
    step_spec = jax.ShapeDtypeStruct((), jnp.float32)
    y_spec = x_spec
    ts_args = zeros_like + zeros_like + zeros_like + [step_spec, x_spec, y_spec]
    ts_lowered = jax.jit(step_fn).lower(*ts_args)
    ts_path = os.path.join(args.out, "fno_train_step.hlo.txt")
    with open(ts_path, "w") as f:
        f.write(to_hlo_text(ts_lowered))

    # --- manifest -------------------------------------------------------------
    manifest = {
        "config": cfg.to_dict(),
        "lr": args.lr,
        "seed": args.seed,
        "params": param_meta,
        "artifacts": {
            "forward": os.path.basename(fwd_path),
            "train_step": os.path.basename(ts_path),
        },
        "signature": {
            "forward_inputs": names + ["x"],
            "train_step_inputs": names
            + [f"m_{n}" for n in names]
            + [f"v_{n}" for n in names]
            + ["step", "x", "y"],
            "train_step_outputs": names
            + [f"m_{n}" for n in names]
            + [f"v_{n}" for n in names]
            + ["step", "loss"],
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    print(
        f"artifacts → {args.out}: forward ({os.path.getsize(fwd_path)//1024} KiB), "
        f"train_step ({os.path.getsize(ts_path)//1024} KiB), "
        f"{len(param_meta)} param tensors"
    )


if __name__ == "__main__":
    main()
